"""Per-request sampling subsystem: mask correctness, counter-PRNG
determinism, submit-time validation, greedy ≡ argmax bit-identity, and
seed reproducibility across batch compositions."""
import numpy as np
import pytest

from repro.serving import Request, SamplingParams, make_prompts
from repro.serving.sampler import (RequestSampler, categorical,
                                   counter_uniform, sampling_probs)


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def test_top_k_mask():
    logits = np.array([3.0, 1.0, 2.0, 0.0, -1.0], np.float32)
    p = sampling_probs(logits, SamplingParams(temperature=1.0, top_k=2))
    assert p[1] == p[3] == p[4] == 0.0
    assert p[0] > p[2] > 0.0
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-12)


def test_top_p_nucleus_keeps_threshold_crosser():
    # probs ~ [0.6, 0.3, 0.1]; top_p=0.7 keeps {0.6, 0.3} (the crosser).
    logits = np.log(np.array([0.6, 0.3, 0.1]))
    p = sampling_probs(logits.astype(np.float32),
                       SamplingParams(temperature=1.0, top_p=0.7))
    assert p[2] == 0.0 and p[0] > 0 and p[1] > 0
    np.testing.assert_allclose(p, [2 / 3, 1 / 3, 0.0], atol=1e-6)


def test_top_p_one_keeps_everything():
    logits = np.random.default_rng(0).normal(size=16).astype(np.float32)
    p = sampling_probs(logits, SamplingParams(temperature=0.7, top_p=1.0))
    assert (p > 0).all()


def test_temperature_sharpens():
    logits = np.array([2.0, 1.0, 0.0], np.float32)
    hot = sampling_probs(logits, SamplingParams(temperature=2.0))
    cold = sampling_probs(logits, SamplingParams(temperature=0.25))
    assert cold[0] > hot[0]                 # low T concentrates on the max
    assert cold[2] < hot[2]


def test_categorical_inverse_cdf():
    p = np.array([0.25, 0.5, 0.25])
    assert categorical(p, 0.0) == 0
    assert categorical(p, 0.3) == 1
    assert categorical(p, 0.95) == 2


# ---------------------------------------------------------------------------
# Counter PRNG
# ---------------------------------------------------------------------------

def test_counter_uniform_is_pure():
    a = counter_uniform(123, 0, 7, 3)
    b = counter_uniform(123, 0, 7, 3)
    assert a == b and 0.0 <= a < 1.0
    assert counter_uniform(123, 0, 8, 3) != a      # counter matters
    assert counter_uniform(124, 0, 7, 3) != a      # seed matters
    assert counter_uniform(123, 1, 7, 3) != a      # stream matters


def test_greedy_sampler_is_exact_argmax():
    rng = np.random.default_rng(3)
    s = RequestSampler(SamplingParams(temperature=0.0, seed=5))
    for i in range(20):
        row = rng.normal(size=64).astype(np.float32)
        assert s.next_token(row, i) == int(np.argmax(row))


def test_sampled_token_depends_only_on_seed_and_index():
    row = np.random.default_rng(1).normal(size=32).astype(np.float32)
    sp = SamplingParams(temperature=0.9, seed=11)
    a = RequestSampler(sp).next_token(row, 4)
    b = RequestSampler(sp).next_token(row, 4)     # fresh sampler, same draw
    assert a == b
    assert isinstance(a, int) and 0 <= a < 32


# ---------------------------------------------------------------------------
# Validation (at submit time)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    SamplingParams(temperature=float("nan")),
    SamplingParams(temperature=-0.5),
    SamplingParams(temperature=float("inf")),
    SamplingParams(temperature=1.0, top_p=0.0),
    SamplingParams(temperature=1.0, top_p=1.5),
    SamplingParams(temperature=1.0, top_p=float("nan")),
    SamplingParams(temperature=1.0, top_k=0),
    SamplingParams(temperature=1.0, top_k=-3),
])
def test_validate_rejects(bad):
    with pytest.raises(ValueError):
        bad.validate()


def test_submit_rejects_bad_params(engine_factory):
    eng = engine_factory("fp16")
    toks = make_prompts("text", 512, 1, 8)[0]
    with pytest.raises(ValueError):
        eng.submit(Request(tokens=toks, max_new_tokens=2,
                           sampling=SamplingParams(temperature=-1.0)))
    with pytest.raises(ValueError):
        eng.submit(Request(tokens=toks, max_new_tokens=2,
                           sampling=SamplingParams(temperature=1.0,
                                                   top_p=2.0)))
    # queue stayed clean — nothing half-admitted
    assert not eng.queue


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

def _drain_tokens(engine, requests):
    handles = [engine.submit(r) for r in requests]
    engine.drain()
    return [h.tokens for h in handles]


def test_greedy_param_identical_to_default(serving_setup, engine_factory):
    """Explicit temperature=0 params and the no-params default are the
    same bit-exact greedy path."""
    cfg, _ = serving_setup
    prompts = [make_prompts("text", cfg.vocab_size, 1, n, seed=n)[0]
               for n in (8, 14, 11)]
    a = _drain_tokens(engine_factory("fp16"), [
        Request(tokens=p, max_new_tokens=6) for p in prompts])
    b = _drain_tokens(engine_factory("fp16"), [
        Request(tokens=p, max_new_tokens=6,
                sampling=SamplingParams(temperature=0.0, seed=s))
        for s, p in enumerate(prompts)])
    assert a == b


def test_seed_reproducible_across_batch_compositions(serving_setup):
    """The same request (same seed) samples the same tokens whether it runs
    alone or beside other traffic — the PRNG is keyed by the request's own
    emission counter, never by batch shape. (Drop-free capacity: MoE drops
    are compute-batch-dependent, the documented parity caveat.)"""
    import jax
    from repro.serving import EngineConfig, InferenceEngine, make_backend
    cfg, params = serving_setup

    def build():
        clone = jax.tree_util.tree_map(lambda x: x, params)
        return InferenceEngine(cfg, clone, make_backend("fp16"),
                               EngineConfig(max_slots=4, max_len=64,
                                            capacity_factor=8.0))

    target = Request(tokens=make_prompts("text", cfg.vocab_size, 1, 12,
                                         seed=5)[0],
                     max_new_tokens=8,
                     sampling=SamplingParams(temperature=0.8, seed=1234))
    alone = _drain_tokens(build(), [target])[0]

    others = [Request(tokens=make_prompts("math", cfg.vocab_size, 1, n,
                                          seed=n)[0],
                      max_new_tokens=8,
                      sampling=SamplingParams(temperature=0.8, seed=50 + n))
              for n in (9, 15)]
    crowded = _drain_tokens(build(), [others[0], target, others[1]])[1]
    assert alone == crowded


def test_different_seeds_diverge(serving_setup):
    """Sanity: at high temperature two seeds should not produce the same
    8-token continuation (deterministic given the fixed seeds here)."""
    import jax
    from repro.serving import EngineConfig, InferenceEngine, make_backend
    cfg, params = serving_setup
    prompt = make_prompts("text", cfg.vocab_size, 1, 12, seed=5)[0]

    def run(seed):
        clone = jax.tree_util.tree_map(lambda x: x, params)
        eng = InferenceEngine(cfg, clone, make_backend("fp16"),
                              EngineConfig(max_slots=2, max_len=64,
                                           capacity_factor=8.0))
        return _drain_tokens(eng, [Request(
            tokens=prompt, max_new_tokens=8,
            sampling=SamplingParams(temperature=1.2, seed=seed))])[0]

    assert run(1) != run(2)


def test_generate_shim_routes_sampling(engine_factory, serving_setup):
    cfg, _ = serving_setup
    prompts = np.asarray(make_prompts("text", cfg.vocab_size, 2, 10))
    eng = engine_factory("fp16")
    out, _, _ = eng.generate({"tokens": prompts}, 5,
                             sampling=SamplingParams(temperature=0.9,
                                                     seed=7))
    assert out.shape == (2, 5)
    with pytest.raises(ValueError):
        eng.generate({"tokens": prompts}, 2,
                     sampling=SamplingParams(temperature=float("nan")))
