"""DeepSeek-LLM-7B — llama-architecture dense model, MHA (kv=32).
[arXiv:2401.02954]"""
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    vocab_size=102400,
    d_ff=11008,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=128,
                    rope_theta=10000.0),
    norm_eps=1e-6,
    max_seq_len=4096,
    source="arXiv:2401.02954 (DeepSeek LLM)",
)
