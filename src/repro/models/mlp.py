"""Dense feed-forward blocks (SwiGLU and GELU variants)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init


def init_swiglu(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d_model, d_ff)),
        "w_up": _init(ks[1], (d_model, d_ff)),
        "w_down": _init(ks[2], (d_ff, d_model)),
    }


def swiglu(p, x: jax.Array) -> jax.Array:
    return (jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
            * (x @ p["w_up"])) @ p["w_down"]


def init_gelu_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 2)
    return {
        "w_up": _init(ks[0], (d_model, d_ff)),
        "w_down": _init(ks[1], (d_ff, d_model)),
    }


def gelu_mlp(p, x: jax.Array) -> jax.Array:
    return jax.nn.gelu((x @ p["w_up"]).astype(jnp.float32)).astype(x.dtype) @ p["w_down"]
