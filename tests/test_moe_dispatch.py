"""MoE dispatch correctness: sort-scatter dispatch vs a naive dense reference,
expert-parallel partition equivalence, counts, drops, and the DynaExq bank."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ver import build_bank, ExpertBankQ
from repro.models.config import MoEConfig
from repro.models.moe import (dispatch_compute, effective_expert_weights,
                              init_moe, moe_apply, moe_capacity, route)


def naive_moe(params, bank, x, cfg):
    """Dense reference: every expert computes every token; gates select."""
    gates, idx, _ = route(params["router"], x, cfg)
    w = effective_expert_weights(bank)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, w["w_gate"])
                    .astype(jnp.float32)).astype(x.dtype)
    h = h * jnp.einsum("td,edf->tef", x, w["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, w["w_down"])  # (T, E, d)
    T = x.shape[0]
    y = jnp.zeros_like(x)
    for j in range(cfg.top_k):
        y = y + y_all[jnp.arange(T), idx[:, j]] * gates[:, j:j + 1].astype(x.dtype)
    return y


def setup(E=8, d=32, f=64, T=24, k=2, seed=0):
    cfg = MoEConfig(num_experts=E, top_k=k, d_ff_expert=f,
                    norm_topk_prob=True)
    key = jax.random.PRNGKey(seed)
    params = init_moe(key, d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, d), jnp.bfloat16)
    return cfg, params, x


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), k=st.sampled_from([1, 2, 4]))
def test_dispatch_matches_naive(seed, k):
    cfg, params, x = setup(k=k, seed=seed)
    cap = moe_capacity(x.shape[0], cfg, 8.0)   # ample: dropless
    y, aux = moe_apply(params, params["experts"], x, cfg, cap)
    want = naive_moe(params, params["experts"], x, cfg)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
    assert float(aux.dropped) == 0.0
    assert int(aux.counts.sum()) == x.shape[0] * cfg.top_k


def test_counts_are_router_selections():
    cfg, params, x = setup()
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    _, aux = moe_apply(params, params["experts"], x, cfg, cap)
    _, idx, _ = route(params["router"], x, cfg)
    want = np.bincount(np.asarray(idx).reshape(-1), minlength=cfg.num_experts)
    np.testing.assert_array_equal(np.asarray(aux.counts), want)


def test_capacity_drop_fraction():
    cfg, params, x = setup(T=64, k=2)
    y, aux = moe_apply(params, params["experts"], x, cfg, capacity=8)
    assert 0.0 <= float(aux.dropped) <= 1.0
    y2, aux2 = moe_apply(params, params["experts"], x, cfg,
                         capacity=moe_capacity(64, cfg, 8.0))
    assert float(aux2.dropped) <= float(aux.dropped)


def test_expert_parallel_partition_equivalence():
    """Sum of per-shard partial outputs (e_offset/e_local) == full output —
    the invariant the shard_map psum relies on."""
    cfg, params, x = setup(E=8, T=16, k=2)
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    gates, idx, _ = route(params["router"], x, cfg)
    full, _, _ = dispatch_compute(params["experts"], x, idx, gates,
                                  cfg.num_experts, cap)
    parts = []
    for off in (0, 4):
        sel = (idx >= off) & (idx < off + 4)
        idx_l = jnp.where(sel, idx - off, 4)
        gates_l = jnp.where(sel, gates, 0.0)
        bank_l = {n: w[off:off + 4] for n, w in params["experts"].items()}
        y, counts_l, _ = dispatch_compute(bank_l, x, idx_l, gates_l, 4, cap)
        parts.append(np.asarray(y, np.float32))
    np.testing.assert_allclose(parts[0] + parts[1],
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_dynaexq_bank_hi_overrides_lo():
    """An expert published to the hi pool computes with exact bf16 weights;
    unpublished experts show int4 quantization error."""
    cfg, params, x = setup(E=4, d=64, f=64, k=1, T=16)
    w = {n: a[None] for n, a in params["experts"].items()}  # add L dim
    bank = build_bank(w, n_hi=2, lo_bits=4)
    # publish expert 1 → slot 0
    bank.slot_map = bank.slot_map.at[0, 1].set(0)
    bank.slot_owner = bank.slot_owner.at[0, 0].set(1)
    for n in bank.hi:
        bank.hi[n] = bank.hi[n].at[0, 0].set(w[n][0, 1])
    sliced = jax.tree_util.tree_map(lambda a: a[0], bank)
    eff = effective_expert_weights(sliced)
    np.testing.assert_array_equal(np.asarray(eff["w_gate"][1]),
                                  np.asarray(params["experts"]["w_gate"][1]))
    assert not np.array_equal(np.asarray(eff["w_gate"][0]),
                              np.asarray(params["experts"]["w_gate"][0]))


def test_moe_capacity_formula():
    cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=16)
    assert moe_capacity(64, cfg, 1.0) >= 64 * 2 // 8
    assert moe_capacity(1, cfg, 1.0) >= 1
    assert moe_capacity(64, cfg, 2.0) % 8 == 0
