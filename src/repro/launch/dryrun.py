import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × input-shape) on the
production mesh, print memory/cost analysis, and emit roofline terms.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above executes before any jax import, giving this process
512 placeholder CPU devices for the 16×16 (and 2×16×16) meshes.

Usage:
    python -m repro.launch.dryrun --arch qwen3-moe-30b-a3b --shape decode_32k
    python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun
    python -m repro.launch.dryrun --arch ... --shape ... --mesh multi
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.shapes import SHAPES, SKIPS, build_dryrun

ASSIGNED = tuple(a for a in ARCH_IDS if a != "qwen3-moe-80b-a3b")


def _compile(arch, shape, mesh, planner_kw, nsb=None, microbatches=1):
    spec = build_dryrun(arch, shape, mesh, planner_kw=planner_kw,
                        nsb_override=nsb, microbatches=microbatches)
    jitted = jax.jit(spec.step_fn,
                     in_shardings=spec.in_shardings,
                     donate_argnums=spec.donate_argnums)
    with mesh:
        compiled = jitted.lower(*spec.args).compile()
    return spec, compiled


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: 0.4.x
    returns a one-element list of dicts, newer jax returns the dict
    directly. Every consumer of the dry-run machinery should come through
    here instead of calling ``.cost_analysis()`` raw."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return ca


def _raw_costs(compiled):
    from repro.launch.roofline import collective_bytes, convert_bytes
    ca = cost_analysis(compiled)
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    detail = {k: v for k, v in coll.items() if k != "_counts"}
    raw = float(ca.get("bytes accessed", 0.0))
    # NOTE: raw CPU-HLO bytes are an UPPER BOUND for the TPU memory term —
    # XLA:CPU legalizes bf16 dots via f32 operand converts at fusion
    # boundaries (TPU MXUs take bf16 natively). Documented in EXPERIMENTS.md.
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": raw, "bytes_raw": raw,
            "coll": {k: float(v) for k, v in detail.items()}}


def _extrapolate(c2, c4, n_full):
    """XLA cost_analysis counts while-loop bodies once. Two compiles at
    nsb=2 / nsb=4 recover body (= (c4−c2)/2) and outside (= c2 − 2·body);
    total(n) = outside + n·body. Clamped at ≥0 per metric."""
    def comb(a, b):
        body = max(0.0, (b - a) / 2.0)
        outside = max(0.0, a - 2.0 * body)
        return outside + n_full * body
    out = {"flops": comb(c2["flops"], c4["flops"]),
           "bytes": comb(c2["bytes"], c4["bytes"]),
           "bytes_raw": comb(c2["bytes_raw"], c4["bytes_raw"]),
           "coll": {k: comb(c2["coll"][k], c4["coll"][k])
                    for k in c2["coll"]}}
    return out


def run_one(arch: str, shape: str, multi_pod: bool, planner_kw=None,
            verbose: bool = True, microbatches: int = 1) -> dict:
    from repro.launch.roofline import Roofline, model_flops
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    # 1) FULL config: must lower+compile (deliverable e); memory from here.
    spec, compiled = _compile(arch, shape, mesh, planner_kw,
                              microbatches=microbatches)
    t_full = time.perf_counter() - t0

    # 2) nsb=2 / nsb=4 variants, scans UNROLLED, for the loop-cost
    # extrapolation (cost_analysis counts rolled loop bodies once).
    # The roofline table is single-pod only (EXPERIMENTS.md §Roofline); the
    # multi-pod pass proves the 'pod' axis lowers and reports memory.
    from repro.models.model import unrolled_scans
    t1 = time.perf_counter()
    if multi_pod:
        costs = _raw_costs(compiled)
    else:
        with unrolled_scans():
            _, c_2 = _compile(arch, shape, mesh, planner_kw, nsb=2,
                              microbatches=microbatches)
            _, c_4 = _compile(arch, shape, mesh, planner_kw, nsb=4,
                              microbatches=microbatches)
        costs = _extrapolate(_raw_costs(c_2), _raw_costs(c_4),
                             spec.cfg.n_superblocks())
    t_extra = time.perf_counter() - t1

    rl = Roofline(
        flops=costs["flops"], hbm_bytes=costs["bytes"],
        coll_bytes=sum(costs["coll"].values()),
        coll_detail={k: int(v) for k, v in costs["coll"].items()},
        chips=chips,
        model_flops=model_flops(spec.cfg, spec.kind, spec.tokens_per_step))

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": spec.kind, "chips": chips,
        "compile_s": round(t_full, 1), "extrapolate_s": round(t_extra, 1),
        "roofline": rl.row(),
        "hbm_gb_raw_cpu_hlo": round(costs["bytes_raw"] / 1e9, 3),
        "collectives": rl.coll_detail,
        "notes": spec.notes,
    }
    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        args_b = rec.get("argument_size_in_bytes", 0)
        temp_b = rec.get("temp_size_in_bytes", 0)
        rec["per_device_hbm_gb"] = round((args_b + temp_b) / 1e9, 3)
        rec["fits_16gb_hbm"] = (args_b + temp_b) <= 16 * (1 << 30)
    if verbose:
        print(json.dumps(rec, indent=2))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="all assigned (arch × shape) pairs")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--seq-shard-cache", type=int, default=1)
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="§Perf variant: shard non-divisible head counts")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    planner_kw = dict(seq_shard_cache=bool(args.seq_shard_cache),
                      pad_heads=bool(args.pad_heads))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    pairs = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                pairs.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    ok = fail = skip = 0
    for arch, shape in pairs:
        for multi in meshes:
            tag = f"{arch}×{shape}×{'2x16x16' if multi else '16x16'}"
            if (arch, shape) in SKIPS:
                print(f"SKIP {tag}: {SKIPS[(arch, shape)]}")
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "skipped": SKIPS[(arch, shape)]}
                skip += 1
            else:
                try:
                    rec = run_one(arch, shape, multi, planner_kw,
                                  verbose=not args.all,
                                  microbatches=args.microbatches)
                    ok += 1
                    rl = rec["roofline"]
                    print(f"OK   {tag}  compile {rec['compile_s']}s  "
                          f"bottleneck={rl['bottleneck']}  "
                          f"hbm/dev={rec.get('per_device_hbm_gb', '?')}GB  "
                          f"useful={rl['useful_flops_ratio']}")
                except Exception as e:  # noqa: BLE001 — record and continue
                    fail += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc()
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    print(f"\ndone: {ok} ok, {fail} failed, {skip} skipped")
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
