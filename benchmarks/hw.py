"""Hardware constants used by the transfer-cost models and rooflines."""
PCIE_GBPS = 16.0       # PCIe gen4 x16 — host↔device tier (paper's A6000 rig)
HBM_GBPS = 819.0       # TPU v5e HBM
PEAK_TFLOPS_BF16 = 197.0
ICI_GBPS = 50.0
