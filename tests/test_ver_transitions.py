"""VER + transition pipeline: the paper's execution contract.

Property tested: whatever the workload does, (i) the published handle table
is always consistent (slot_map↔slot_owner bijective on resident experts),
(ii) the byte budget is never exceeded, (iii) the forward pass always sees a
fully-materialized version (hi slots referenced by slot_map hold exactly the
host-side hi weights).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (ControllerConfig, DynaExqController, build_bank,
                        expert_hi_nbytes)
from repro.core.ver import Residency


def make_controller(L=2, E=8, K=64, N=32, n_hi=3, margin=0.0,
                    rate_experts=0):
    key = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(key, (L, E, K, N), jnp.float32)
         .astype(jnp.bfloat16)}
    bank = build_bank(w, n_hi=n_hi, lo_bits=4)
    host = {k: np.asarray(v) for k, v in w.items()}
    hib = expert_hi_nbytes({k: v.shape for k, v in w.items()})
    ctl = DynaExqController(
        bank, host, n_hi_per_layer=n_hi, hi_bytes_per_expert=hib,
        cfg=ControllerConfig(update_interval_s=0.0, alpha=0.5, margin=margin,
                             migration_bytes_per_window=rate_experts * hib))
    return ctl, host, hib


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), windows=st.integers(1, 8))
def test_invariants_under_random_workload(seed, windows):
    ctl, host, hib = make_controller()
    rng = np.random.default_rng(seed)
    for _ in range(windows):
        counts = rng.integers(0, 50, size=(2, 8))
        ctl.observe(counts)
        ctl.update()
        ctl.tm.check_invariants()
    ctl.flush()
    ctl.tm.check_invariants()
    # published hi slots contain exactly the host hi bytes
    sm = ctl.tm.slot_map_h
    hi = np.asarray(ctl.bank.hi["w"])
    for l in range(2):
        for e in range(8):
            if sm[l, e] >= 0:
                np.testing.assert_array_equal(hi[l, sm[l, e]], host["w"][l, e])


def test_hot_experts_become_resident():
    ctl, _, _ = make_controller()
    counts = np.zeros((2, 8), np.int64)
    counts[:, [1, 4, 6]] = [100, 80, 60]
    for _ in range(3):
        ctl.observe(counts)
        ctl.update()
    ctl.flush()
    for l in range(2):
        assert ctl.tm.hi_set(l) == {1, 4, 6}


def test_workload_shift_swaps_residency():
    ctl, _, _ = make_controller(n_hi=2)
    a = np.zeros((2, 8), np.int64); a[:, [0, 1]] = 100
    b = np.zeros((2, 8), np.int64); b[:, [6, 7]] = 100
    for _ in range(3):
        ctl.observe(a); ctl.update()
    ctl.flush()
    assert ctl.tm.hi_set(0) == {0, 1}
    for _ in range(8):   # EMA needs a few windows to cross over
        ctl.observe(b); ctl.update()
    ctl.flush()
    assert ctl.tm.hi_set(0) == {6, 7}
    assert ctl.tm.stats["demoted"] >= 4


def test_migration_rate_limit_defers():
    """Bounded interference: with a rate limit of one expert per window,
    promotions trickle instead of bursting."""
    ctl, _, hib = make_controller(n_hi=3, rate_experts=1)
    counts = np.zeros((2, 8), np.int64)
    counts[:, [1, 4, 6]] = [100, 80, 60]
    ctl.observe(counts)
    ctl.update()
    promoted_after_one = ctl.tm.stats["promoted"]
    assert promoted_after_one <= 2  # ≤ 1 admitted per layer window
    for _ in range(10):
        ctl.observe(counts); ctl.update()
    ctl.flush()
    assert ctl.tm.hi_set(0) == {1, 4, 6}   # eventually converges


def test_budget_accounting_exact():
    ctl, _, hib = make_controller(n_hi=2)
    counts = np.zeros((2, 8), np.int64)
    counts[:, [2, 3]] = 50
    ctl.observe(counts); ctl.update(); ctl.flush()
    resident = int((ctl.tm.slot_map_h >= 0).sum())
    assert ctl.tracker.used == int(resident) * hib
    assert ctl.tracker.used <= ctl.tracker.cap


def test_publish_ready_probes_each_copy_not_the_bank(monkeypatch):
    """Regression: readiness used to be probed on one leaf of the CURRENT
    ``bank.hi`` — which every later ``_issue_copy`` overwrites — so an older
    pending promotion could publish based on a newer copy's readiness. Each
    ``PendingPromotion`` now carries its own result arrays: with only the
    FIRST copy's arrays reporting ready, exactly that promotion publishes."""
    from repro.core import transitions as T
    ctl, _, _ = make_controller(n_hi=3)
    ctl.tm.request_promotion(0, 1)
    ctl.tm.drain()                            # pending A (issued first)
    ctl.tm.request_promotion(0, 4)
    ctl.tm.drain()                            # pending B overwrites bank.hi
    pend = ctl.tm._pending
    assert len(pend) == 2
    assert all(p.arrays for p in pend)
    assert set(map(id, pend[0].arrays)).isdisjoint(map(id, pend[1].arrays))
    ready_ids = {id(a) for a in pend[0].arrays}
    monkeypatch.setattr(T, "_is_ready", lambda a: id(a) in ready_ids)
    published = ctl.tm.publish_ready()
    assert published == 1
    assert ctl.tm.hi_set(0) == {1}            # A published, B still pending
    assert ctl.tm.pending_experts(0) == {4}
    monkeypatch.undo()
    ctl.tm.publish_ready(wait=True)
    assert ctl.tm.hi_set(0) == {1, 4}
    ctl.tm.check_invariants()


def test_demote_while_promoting_reclaims():
    ctl, _, _ = make_controller(n_hi=1)
    a = np.zeros((2, 8), np.int64); a[:, 0] = 100
    ctl.observe(a)
    ctl.tm.request_promotion(0, 0)
    ctl.tm.drain()
    # demote before publish
    ctl.tm.state[0, 0] = Residency.DEMOTING.value
    ctl.tm.evict_q.append((0, 0))
    ctl.tm.drain()
    ctl.tm.publish_ready(wait=True)
    assert ctl.tm.slot_map_h[0, 0] == -1
    assert ctl.tm.pools[0].n_free == 1
    assert ctl.tracker.used == 0
