"""Post-training quantization entry points.

``quantize_expert_bank`` prepares the two DynaExq weight tiers for a stacked
expert bank; ``quantize_tree`` applies uniform static PTQ to a whole param
pytree (the paper's static baseline) while leaving norms/embeddings/router in
high precision — the standard weight-only PTQ recipe (GPTQ/AWQ-style scoping,
RTN rounding).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.quant.qtensor import QuantizedTensor, quantize

# Param-name fragments that are never quantized (tiny and/or precision-critical).
_PTQ_SKIP = ("norm", "embed", "router", "bias", "scale", "ln_", "a_log", "dt_bias", "conv")


def _quantizable(path: str, leaf: Any, min_size: int) -> bool:
    if not isinstance(leaf, jax.Array) and not hasattr(leaf, "shape"):
        return False
    if any(s in path for s in _PTQ_SKIP):
        return False
    if getattr(leaf, "ndim", 0) < 2:
        return False
    return leaf.size >= min_size


def quantize_tree(params, bits: int, group_size: int = 64,
                  min_size: int = 1 << 14,
                  predicate: Callable[[str, Any], bool] | None = None):
    """Uniform static PTQ over a param pytree. Returns a tree where matmul
    weights are replaced by :class:`QuantizedTensor` leaves."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)

    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path).lower()
        take = predicate(name, leaf) if predicate else _quantizable(name, leaf, min_size)
        if take and leaf.shape[-2] % group_size == 0:
            out.append(quantize(leaf, bits=bits, group_size=group_size))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def quantize_expert_bank(w: jax.Array, lo_bits: int, group_size: int = 64):
    """Quantize a stacked expert weight ``(E, K, N)`` into the lo tier.

    Returns the lo-precision :class:`QuantizedTensor` with leading expert dim.
    The hi tier is either the original bf16 (hi_bits=16) or a higher-bit
    QuantizedTensor prepared separately.
    """
    return quantize(w, bits=lo_bits, group_size=group_size)


def dequant_or_identity(leaf, dtype=jnp.bfloat16):
    if isinstance(leaf, QuantizedTensor):
        return leaf.dequantize(dtype)
    return leaf


def dequantize_tree(params, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda l: dequant_or_identity(l, dtype),
        params,
        is_leaf=lambda l: isinstance(l, QuantizedTensor),
    )
