from repro.training.adamw import adamw_init, adamw_update, AdamWConfig
from repro.training.train import TrainConfig, make_train_step, train_loop, loss_fn
from repro.training.data import SyntheticLMTask
from repro.training.checkpoint import save_checkpoint, load_checkpoint

__all__ = [
    "adamw_init", "adamw_update", "AdamWConfig", "TrainConfig",
    "make_train_step", "train_loop", "loss_fn", "SyntheticLMTask",
    "save_checkpoint", "load_checkpoint",
]
