"""LLaVA-NeXT-34B — VLM language backbone; anyres-tiling ViT frontend is a
STUB (input_specs supplies patch embeddings). [hf:llava-hf/llava-v1.6]"""
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    vocab_size=64000,
    d_ff=20480,
    attn=AttnConfig(n_heads=56, n_kv_heads=8, head_dim=128,
                    rope_theta=5000000.0),
    num_image_tokens=576,   # one anyres base tile (24×24 patches)
    norm_eps=1e-5,
    max_seq_len=131072,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (scaled to the 34B backbone)",
)
