"""Distribution context: lets model code (notably the MoE layer) opt into
shard_map expert parallelism when a mesh is active, while staying pure jnp on
a single device.

GSPMD auto-sharding handles every dense layer well, but MoE dispatch is
data-dependent (sort/scatter by expert id): the partitioner cannot shard a
global argsort and replicates the (tokens×top_k, d_model) gather — a ~68 GB
buffer at train_4k scale. The production formulation makes dispatch LOCAL:
each data shard routes its own tokens, each model shard computes only its
E/16 experts, and partial outputs reduce with one psum over 'model' per MoE
layer. ``dist_ctx`` carries the mesh + axis names into the model layers.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: object
    dp_axes: Tuple[str, ...]      # ('pod', 'data') or ('data',)
    model_axis: str = "model"
    tokens_dp_sharded: bool = True   # False for batch-1 long-context decode
    # Expert-parallel serving mode: tokens shard over dp_axes AND the model
    # axis (every device owns T/n_token_shards tokens plus E/model_size
    # experts), and the MoE layer runs the ragged all-to-all pipeline —
    # route locally, exchange compacted rows to the owning expert shard,
    # compute with the shard's resident tier, exchange results back. See
    # ``models.moe._moe_local_ep``.
    tokens_ep_sharded: bool = False

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def n_token_shards(self) -> int:
        """Shards the token dim splits over (EP: data × model; else data)."""
        return self.dp_size * (self.model_size if self.tokens_ep_sharded
                               else 1)


def ep_context(mesh, model_axis: str = "model") -> DistContext:
    """Expert-parallel serving context over ``mesh``: every non-model axis
    data-shards tokens, the model axis owns experts AND a token slice."""
    dp = tuple(a for a in mesh.axis_names if a != model_axis)
    return DistContext(mesh=mesh, dp_axes=dp, model_axis=model_axis,
                       tokens_dp_sharded=True, tokens_ep_sharded=True)


def get_dist() -> Optional[DistContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def dist_ctx(ctx: Optional[DistContext]):
    prev = get_dist()
    _STATE.ctx = ctx
    try:
        yield
    finally:
        _STATE.ctx = prev
