"""Shared benchmark substrate: one tiny-but-real MoE trained on the synthetic
LM task, cached on disk so every benchmark measures the same trained model
(the paper's quality claims are meaningless on random weights)."""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.training import (SyntheticLMTask, TrainConfig, load_checkpoint,
                            save_checkpoint, train_loop)
from repro.training.adamw import AdamWConfig

CKPT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "bench_model")

#: CI smoke mode: shrink training and sweep sizes so the benchmark path can
#: be exercised end-to-end in seconds (set ``BENCH_SMOKE=1``).
BENCH_SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def bench_config():
    """A granite-family MoE sized for CPU benchmarking: 4 layers, 8 experts
    top-2 — small enough to serve in seconds, big enough to show skew."""
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    cfg = dataclasses.replace(
        cfg, name="bench-moe", n_layers=4,
        moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                router_aux_coef=0.002))
    return cfg


def trained_model(steps: int = 120, force: bool = False):
    if BENCH_SMOKE:
        steps = min(steps, 12)
    cfg = bench_config()
    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    params0 = init_params(jax.random.PRNGKey(0), cfg)
    if not force and os.path.exists(os.path.join(CKPT, "manifest.json")):
        try:
            params, _ = load_checkpoint(CKPT, params0)
            return cfg, params, task
        except Exception:
            pass
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=2e-3, warmup_steps=10,
                                             total_steps=steps))
    params, _, hist = train_loop(cfg, params0, task.batches(16, 65, steps),
                                 tcfg, log_every=steps, log=lambda *_: None)
    save_checkpoint(CKPT, params, step=steps)
    return cfg, params, task


def eval_batches(task, cfg, n=6, batch=8, length=65, workload=None, seed=777):
    """Held-out eval batches; optionally conditioned on a serving workload's
    token distribution (for the shift experiments)."""
    from repro.serving.requests import make_prompts
    for i in range(n):
        if workload is None:
            toks = task.sample(batch, length, seed=seed + i)
        else:
            toks = make_prompts(workload, cfg.vocab_size, batch, length,
                                seed=seed + i)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def clone(tree):
    return jax.tree_util.tree_map(lambda x: x, tree)


def bench_backend(kind, controller=None):
    """Shared baseline construction for the serving benchmarks: every figure
    (serving_perf, prompt_scaling, ...) compares the SAME budget settings —
    int4 lo tier, n_hi=2, a 2-expert offload cache at the measured PCIe —
    so rows stay comparable across figures."""
    from benchmarks.hw import PCIE_GBPS
    from repro.serving import OffloadConfig, make_backend
    if kind == "static":
        return make_backend("static", lo_bits=4)
    if kind == "dynaexq":
        return make_backend("dynaexq", lo_bits=4, n_hi_per_layer=2,
                            controller=controller)
    if kind == "offload":
        return make_backend("offload", ocfg=OffloadConfig(
            cache_experts_per_layer=2, pcie_gbps=PCIE_GBPS))
    return make_backend(kind)
