"""Distribution correctness: the shard_map expert-parallel MoE must compute
EXACTLY what the single-device path computes. Runs in a subprocess with 8
host devices (jax locks the device count at first init, and the rest of the
suite runs single-device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.launch.dist import DistContext, dist_ctx
from repro.launch.sharding import ShardingPlanner
from repro.models import decode_step, init_caches, init_params, prefill

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("granite-moe-1b-a400m", reduced=True)
# reduced: E=4 experts over model=4 → 1 expert/rank
key = jax.random.PRNGKey(0)

# f32 params AND caches: the once-xfailed divergence here was bf16
# reduction-order noise (GSPMD contraction-sharded dense projections plus
# the bf16 MoE combine accumulate in different orders across shards)
# flipping near-tie router top-k picks. In f32 the two paths agree to
# float rounding — the sharded formulation itself is exact.
def _f32(t):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32)
        if hasattr(x, "dtype") and x.dtype == jnp.bfloat16 else x, t)

params = _f32(init_params(key, cfg))
B, S = 4, 16
toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size)

# single-device reference
caches = _f32(init_caches(cfg, B, 64))
lg_ref, caches_ref, counts_ref = prefill(
    params, cfg, {"tokens": toks[:, :S]}, caches, capacity_factor=8.0)
tok = toks[:, S]
lg2_ref, _, _ = decode_step(params, cfg, tok, jnp.int32(S), caches_ref,
                            capacity_factor=8.0)

# sharded: same computation under mesh + dist ctx + planner shardings
dctx = DistContext(mesh=mesh, dp_axes=("data",), tokens_dp_sharded=True)
planner = ShardingPlanner(cfg, mesh)
params_sh = planner.tree_shardings(params, "param")
caches0 = _f32(init_caches(cfg, B, 64))
caches_sh = planner.tree_shardings(caches0, "cache")

def pf(p, b, c):
    with dist_ctx(dctx):
        return prefill(p, cfg, b, c, capacity_factor=8.0)

def dc(p, t, i, c):
    with dist_ctx(dctx):
        return decode_step(p, cfg, t, i, c, capacity_factor=8.0)

with mesh:
    params_d = jax.device_put(params, params_sh)
    caches_d = jax.device_put(caches0, caches_sh)
    lg, caches1, counts = jax.jit(pf)(params_d, {"tokens": toks[:, :S]}, caches_d)
    lg2, _, counts2 = jax.jit(dc)(params_d, tok, jnp.int32(S), caches1)

out = {
  "prefill_max_err": float(jnp.max(jnp.abs(lg - lg_ref))),
  "decode_max_err": float(jnp.max(jnp.abs(lg2 - lg2_ref))),
  "counts_equal": bool((np.asarray(counts["0"]) == np.asarray(counts_ref["0"])).all()),
  "prefill_scale": float(jnp.max(jnp.abs(lg_ref))),
}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_shard_map_moe_matches_single_device():
    """The GSPMD-sharded MoE forward matches single-device to float
    rounding. Run in f32 so reduction-order noise cannot flip near-tie
    router picks (the root cause of the historical xfail here)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    scale = max(out["prefill_scale"], 1.0)
    assert out["prefill_max_err"] <= 1e-4 * scale, out
    assert out["decode_max_err"] <= 1e-4 * scale, out
    assert out["counts_equal"], out


DRYRUN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import repro.launch.shapes as shapes
import repro.launch.dryrun as dr
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
# shrink the shape set for an 8-device smoke of the REAL dry-run machinery
shapes.SHAPES["tiny_decode"] = dict(kind="decode", seq=128, batch=8)
shapes.SHAPES["tiny_train"] = dict(kind="train", seq=64, batch=8)
import repro.configs as C
import dataclasses
orig = C.get_config
def patched(name, reduced=False):
    cfg = orig(name, reduced=True)
    return cfg
C.get_config = patched
shapes.get_config = patched
for shape in ("tiny_decode", "tiny_train"):
    spec = shapes.build_dryrun("granite-moe-1b-a400m", shape, mesh)
    jitted = jax.jit(spec.step_fn, in_shardings=spec.in_shardings,
                     donate_argnums=spec.donate_argnums)
    with mesh:
        compiled = jitted.lower(*spec.args).compile()
    print("COMPILED", shape, dr.cost_analysis(compiled).get("flops", 0) > 0)
"""


@pytest.mark.slow
def test_dryrun_machinery_small_multipod_mesh():
    """The real build_dryrun/planner path lowers+compiles on a (2,2,2)
    multi-pod debug mesh — including the MoE serving bank and train step."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", DRYRUN_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert r.stdout.count("COMPILED") == 2
    assert "False" not in r.stdout
