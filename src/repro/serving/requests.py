"""Synthetic request workloads with controllable routing skew & shift.

The paper's Fig. 2 shows the hot expert set is disjoint across text / math /
code workloads. We reproduce the *mechanism* without real datasets: each
workload draws tokens Zipf-distributed over a workload-specific slice of the
vocabulary. Different input statistics → different embedding clusters →
different router hot sets (measured, not assumed — see
benchmarks/workload_shift.py).

Two granularities:

* ``make_prompts`` / ``mixed_stream`` — fixed-shape token batches (training
  eval, hotness measurement);
* ``Request`` / ``RequestStream`` — the serving-engine unit of work:
  variable-length prompts with arrival times and per-request workload tags,
  feeding ``InferenceEngine.submit`` (the same shifting mix as
  ``mixed_stream``, request- rather than batch-shaped).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sampler import SamplingParams

WORKLOADS = ("text", "math", "code")


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus generation and accounting tags.

    ``sampling``: per-request ``SamplingParams`` (temperature / top-k /
    top-p / seed). ``None`` means greedy — bit-identical to pre-sampler
    engines. Validated at ``InferenceEngine.submit``."""
    tokens: np.ndarray                   # (prompt_len,) int32
    max_new_tokens: int = 16
    workload: str = "text"               # which traffic phase produced it
    arrival_s: float = 0.0               # offset from stream start
    eos_token_id: Optional[int] = None
    sampling: Optional[SamplingParams] = None


class RequestStream:
    """Request-level arrival process over shifting workload phases.

    ``phases``: sequence of ``(workload, n_requests)`` — the same shifting
    serving mix ``mixed_stream`` yields batch-wise, one ``Request`` at a
    time. Arrivals are Poisson at ``arrival_rate_rps`` (or back-to-back when
    ``None``); prompt lengths jitter uniformly within
    ``prompt_len ± prompt_len_jitter`` so continuous batching sees genuinely
    variable-length work.
    """

    def __init__(self, vocab_size: int,
                 phases: Sequence[Tuple[str, int]],
                 prompt_len: int = 32,
                 prompt_len_jitter: int = 0,
                 max_new_tokens: int = 8,
                 arrival_rate_rps: Optional[float] = None,
                 seed: int = 0,
                 sampling: Optional[SamplingParams] = None):
        self.vocab_size = vocab_size
        self.phases = list(phases)
        self.prompt_len = prompt_len
        self.prompt_len_jitter = prompt_len_jitter
        self.max_new_tokens = max_new_tokens
        self.arrival_rate_rps = arrival_rate_rps
        self.seed = seed
        # Per-request sampling params: every request in the stream carries
        # its own seed (base seed + request ordinal) so replaying the
        # stream is reproducible while rows stay decorrelated.
        self.sampling = sampling

    def __len__(self) -> int:
        return sum(n for _, n in self.phases)

    def __iter__(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed ^ 0x5EED)
        now = 0.0
        ordinal = 0
        for pi, (workload, n_requests) in enumerate(self.phases):
            for j in range(n_requests):
                lo = max(1, self.prompt_len - self.prompt_len_jitter)
                hi = self.prompt_len + self.prompt_len_jitter
                length = int(rng.integers(lo, hi + 1))
                toks = make_prompts(workload, self.vocab_size, 1, length,
                                    seed=self.seed + 1009 * pi + j)[0]
                if self.arrival_rate_rps:
                    now += float(rng.exponential(1.0 / self.arrival_rate_rps))
                sampling = None
                if self.sampling is not None:
                    sampling = dataclasses.replace(
                        self.sampling, seed=self.sampling.seed + ordinal)
                yield Request(tokens=toks, max_new_tokens=self.max_new_tokens,
                              workload=workload, arrival_s=now,
                              sampling=sampling)
                ordinal += 1


def _zipf_probs(n: int, s: float = 1.2) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** s
    return p / p.sum()


def make_prompts(workload: str, vocab_size: int, batch: int, length: int,
                 seed: int = 0) -> np.ndarray:
    """(batch, length) int32 token ids for one workload."""
    if workload not in WORKLOADS:
        raise KeyError(f"unknown workload {workload!r}")
    wi = WORKLOADS.index(workload)
    rng = np.random.default_rng(seed + 1000 * wi)
    # Each workload occupies a third of the vocab, shuffled so slices are not
    # trivially ordered; heavy-tailed within the slice.
    perm = np.random.default_rng(42).permutation(vocab_size)
    lo = wi * vocab_size // 3
    hi = (wi + 1) * vocab_size // 3
    slice_ids = perm[lo:hi]
    probs = _zipf_probs(len(slice_ids))
    draws = rng.choice(len(slice_ids), size=(batch, length), p=probs)
    return slice_ids[draws].astype(np.int32)


def mixed_stream(vocab_size: int, batch: int, length: int, phases,
                 seed: int = 0):
    """Yield (workload_name, prompts) per phase — the shifting serving mix."""
    for i, (workload, n_batches) in enumerate(phases):
        for j in range(n_batches):
            yield workload, make_prompts(workload, vocab_size, batch, length,
                                         seed=seed + 17 * i + j)
