"""Counters / gauges / histograms registry with per-step sampling.

The registry complements the flight recorder: where the trace records
*events*, metrics record *levels* — per-step gauges (active experts, pad
ratio, residency mix, budget headroom, queue depths, acceptance EMA),
monotone counters, and latency histograms (promotion publish latency).

Two sinks:

* ``to_prometheus()`` — Prometheus text exposition (scrape or dump);
* a JSONL sink — ``sample(**row)`` appends one flat JSON object per engine
  step, the easy input for pandas/jq and the obs benchmark.

Everything here is plain host-side Python; like the recorder, the engine
only touches it behind ``metrics is not None`` guards.
"""
from __future__ import annotations

import json
from typing import Dict, IO, List, Optional, Sequence

import numpy as np

_DEF_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0)


class Counter:
    """Monotone counter."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        self.value += v


class Gauge:
    """Last-write-wins level."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Cumulative-bucket histogram that also keeps a bounded raw sample so
    exact percentiles (promotion publish p50/p95) stay available without a
    bucket-interpolation fudge."""

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = _DEF_BUCKETS,
                 max_samples: int = 1 << 16):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)   # +inf bucket
        self.total = 0
        self.sum = 0.0
        self._samples: List[float] = []
        self._max_samples = max_samples

    def observe(self, v: float) -> None:
        v = float(v)
        self.total += 1
        self.sum += v
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        if len(self._samples) < self._max_samples:
            self._samples.append(v)

    def percentile(self, p: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples), p))


class MetricsRegistry:
    """Name-keyed metric store + samplers. Metric creation is memoized, so
    instrumentation sites call ``registry.gauge("x").set(v)`` unconditionally
    without registration ceremony."""

    def __init__(self, jsonl_path: Optional[str] = None):
        self._metrics: Dict[str, object] = {}
        self._jsonl: Optional[IO] = None
        self.jsonl_path = jsonl_path
        if jsonl_path:
            self._jsonl = open(jsonl_path, "w")

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = _DEF_BUCKETS) -> Histogram:
        return self._get(name, Histogram, help=help, buckets=buckets)

    # -- sinks -------------------------------------------------------------
    def sample(self, **row) -> None:
        """Append one JSONL record (no-op without a configured sink).
        Callers pass the per-step values explicitly — the record is the
        step's snapshot, not the registry dump."""
        if self._jsonl is None:
            return
        self._jsonl.write(json.dumps(row, sort_keys=True) + "\n")

    def snapshot(self) -> Dict[str, float]:
        """Flat name → value view (histograms export count/sum/p50/p95)."""
        out: Dict[str, float] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[f"{name}_count"] = float(m.total)
                out[f"{name}_sum"] = m.sum
                out[f"{name}_p50"] = m.percentile(50)
                out[f"{name}_p95"] = m.percentile(95)
            else:
                out[name] = m.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                kind = "counter"
            elif isinstance(m, Gauge):
                kind = "gauge"
            else:
                kind = "histogram"
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, Histogram):
                cum = 0
                for b, c in zip(m.buckets, m.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{b:g}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.total}')
                lines.append(f"{name}_sum {m.sum:g}")
                lines.append(f"{name}_count {m.total}")
            else:
                lines.append(f"{name} {m.value:g}")
        return "\n".join(lines) + "\n"

    def close(self) -> None:
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None
