"""Paper Fig. 10 (and Fig. 1's motivation): TTFT vs prompt length. Longer
prompts densify expert activation; offloading pays transfer stalls that grow
with the activated set, DynaExq and static PTQ do not. All baselines run as
backends behind the same InferenceEngine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_backend, clone, trained_model
from repro.serving import EngineConfig, InferenceEngine, Request


def _measure_ttft(kind, cfg, params, bs, toks):
    eng = InferenceEngine(cfg, clone(params), bench_backend(kind),
                          EngineConfig(max_slots=bs, max_len=256))
    handles = [eng.submit(Request(tokens=toks[b], max_new_tokens=1))
               for b in range(bs)]
    eng.drain()
    return float(np.mean([h.ttft_s for h in handles]))


def run(report):
    cfg, params, task = trained_model()
    bs = 4
    for plen in (16, 64, 192):
        toks = np.asarray(task.sample(bs, plen, seed=plen))
        row = {}
        for kind in ("static", "dynaexq", "offload"):
            _measure_ttft(kind, cfg, params, bs, toks)   # warm-up compile
            ttft = _measure_ttft(kind, cfg, params, bs, toks)
            row[kind] = ttft
            report(f"prompt_scaling/ttft/{kind}/len{plen}", ttft * 1e6,
                   round(ttft, 4))
        report(f"prompt_scaling/offload_overhead_x/len{plen}", 0.0,
               round(row["offload"] / row["static"], 2))
