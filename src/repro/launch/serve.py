"""Serving launcher.

On this CPU container it runs the reduced configs end to end (the full
configs are exercised by the dry-run); on a real TPU slice the same command
serves the full config under the production mesh:

    python -m repro.launch.serve --arch granite-moe-1b-a400m --mode dynaexq \
        --batch 4 --prompt-len 32 --new-tokens 16 [--full]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import MoEServer, ServeConfig, make_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m", choices=ARCH_IDS)
    ap.add_argument("--mode", default="dynaexq",
                    choices=["dynaexq", "static", "fp16"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--lo-bits", type=int, default=4, choices=[2, 4, 8])
    ap.add_argument("--n-hi", type=int, default=2)
    ap.add_argument("--hbm-gb", type=float, default=None,
                    help="derive n_hi from a device envelope instead")
    ap.add_argument("--full", action="store_true",
                    help="full (assigned) config — needs a real accelerator")
    ap.add_argument("--workload", default="text")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    print(f"[serve] {cfg.name} mode={args.mode} devices={jax.device_count()}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = MoEServer(
        cfg, params,
        ServeConfig(mode=args.mode, lo_bits=args.lo_bits,
                    n_hi_per_layer=None if args.hbm_gb else args.n_hi,
                    hbm_gb=args.hbm_gb,
                    max_len=args.prompt_len + args.new_tokens + 8,
                    controller=ControllerConfig(update_interval_s=0.25)),
        batch=args.batch)
    toks = jnp.asarray(make_prompts(args.workload, cfg.vocab_size,
                                    args.batch, args.prompt_len))
    t0 = time.perf_counter()
    out, ttft, times = srv.generate({"tokens": toks}, args.new_tokens)
    srv.flush()
    wall = time.perf_counter() - t0
    tput = args.batch * args.new_tokens / wall
    print(f"[serve] TTFT {ttft*1e3:.1f} ms  TPOP "
          f"{1e3*sum(times)/max(len(times),1):.1f} ms  "
          f"throughput {tput:.2f} tok/s")
    if srv.controllers:
        ctl = next(iter(srv.controllers.values()))
        print(f"[serve] transitions: {ctl.tm.stats}")
        print(f"[serve] resident expert bytes: {srv.expert_device_bytes():,}")


if __name__ == "__main__":
    main()
