from repro.serving.backends import (BACKENDS, DynaExqBackend, Fp16Backend,
                                    LRUSet, OffloadBackend, OffloadConfig,
                                    ResidencyBackend, STAT_KEYS,
                                    StaticPTQBackend, make_backend)
from repro.serving.engine import (EngineConfig, EngineStallError,
                                  InferenceEngine, RequestHandle,
                                  RequestState)
from repro.serving.hoststore import FetchModel, HostExpertStore
from repro.serving.kvpool import KVBlockPool, KVLease, TRASH_BLOCK
from repro.serving.prefix import PrefixTrie
from repro.serving.requests import (Request, RequestStream, WORKLOADS,
                                    make_prompts, mixed_stream)
from repro.serving.sampler import (GREEDY, RequestSampler, SamplingParams,
                                   counter_uniform, sampling_probs)
from repro.serving.scheduler import (QOS_CLASSES, Scheduler, SchedulerConfig,
                                     SlotSnapshot, TieredQueue, WORKLOAD_QOS,
                                     resolve_qos)
from repro.serving.spec import SpecDecoder, accept_burst, all_lo_banks
from repro.serving.streaming import (ShardSource, hotness_stage_order,
                                     load_streaming_params,
                                     save_expert_shards)

__all__ = [
    "BACKENDS", "DynaExqBackend", "EngineConfig", "EngineStallError",
    "FetchModel",
    "Fp16Backend", "GREEDY", "HostExpertStore",
    "InferenceEngine", "KVBlockPool", "KVLease", "LRUSet", "OffloadBackend",
    "OffloadConfig", "PrefixTrie", "QOS_CLASSES", "Request", "RequestHandle",
    "RequestSampler", "RequestState", "RequestStream", "ResidencyBackend",
    "STAT_KEYS", "SamplingParams", "Scheduler", "SchedulerConfig",
    "ShardSource", "SlotSnapshot", "SpecDecoder", "StaticPTQBackend",
    "TRASH_BLOCK", "TieredQueue", "WORKLOADS", "WORKLOAD_QOS",
    "accept_burst", "all_lo_banks", "counter_uniform",
    "hotness_stage_order", "load_streaming_params", "make_backend",
    "make_prompts", "mixed_stream", "resolve_qos", "sampling_probs",
    "save_expert_shards",
]
