"""Flight recorder: a bounded ring buffer of typed serving events.

The recorder is the serving stack's black box. Every interesting host-side
transition — engine steps, admissions, preemptions, the promotion pipeline's
``issue → copy → publish`` phases, EP ownership migrations, host-tier demand
fetches, speculative rounds, shed/downgrade decisions — lands here as a
typed event stamped on the **engine clock** (``InferenceEngine._now``):
wall time normally, the virtual clock under ``replay(realtime=False)``, so
CI replays produce byte-identical trace files while realtime runs produce
perfetto-viewable timelines.

Design constraints, in order:

* **Zero cost when absent.** No recorder instance ⇒ no event objects, no
  dict building, nothing — every instrumentation site guards on
  ``tracer is not None`` before touching arguments. The decode hot path is
  identical with observability disabled.
* **Bounded.** The buffer is a ``deque(maxlen=capacity)``; overflow drops
  the oldest events and counts them (``dropped``) instead of growing.
* **Deterministic export.** ``save()`` emits Chrome trace-event JSON with
  sorted keys and no wall-clock metadata, so two runs with identical event
  streams write identical bytes.

Event vocabulary (``name`` / ``cat``):

========================  ==========  =========================================
name                      cat         args
========================  ==========  =========================================
``step``                  engine      step, active, queued, active_experts,
                                      hi/lo/host residency cells, headroom
``moe_forward``           moe         routed, layers, active, active_hi,
                                      active_lo, active_host, published_hi,
                                      tokens, prefill — the cost model's input
``submit``/``shed``/      sched       rid, qos
``downgrade``/
``shed_expired``
``admit``/``finish``/     sched       rid, slot (…)
``preempt``/``resume``
``promo_request``/        residency   layer, expert (…)
``demo_request``/
``demotion``/
``promo_deferred``
``promotion``             residency   async span: begin at copy issue
                                      (layer/expert/slot/bytes), end at
                                      publish (published=1) or cancellation
``ep_migration``          residency   layer, e, f, bytes
``host_fetch``            host        pos, n, bytes, stall_s
``host_stage``/           host        layer(s), n, bytes
``lo_publish``
``spec_round``            spec        rows, drafted, accepted
``fault_injected``        fault       site, kind, seq (injector fired)
``retry``                 fault       site, attempt, backoff_s — includes
                                      host demand-fetch re-reads
``fault_cancel``          fault       layer, expert, reason (promotion or
                                      migration aborted after retries)
``promo_timeout``         fault       layer, expert, age_s (watchdog
                                      cancelled a stuck promotion)
``watchdog_cancel``       fault       rid, idle_s (no-progress request
                                      preempted and requeued)
``quarantine``            fault       layer, n, experts served from host
                                      until their lo rows re-stage
========================  ==========  =========================================
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class TraceEvent:
    """One flight-recorder entry. ``ph`` follows the Chrome trace-event
    phase vocabulary: ``i`` instant, ``B``/``E`` duration span,
    ``b``/``e`` async span (paired by ``id``)."""

    ts: float                       # seconds on the engine clock
    ph: str
    name: str
    cat: str = ""
    id: Optional[int] = None        # async-span correlation id
    args: Optional[Dict] = None


class FlightRecorder:
    """Bounded typed-event ring buffer with a span API and Chrome export.

    ``clock`` is injected by the engine (``engine._now``) so replay runs
    under the virtual clock produce deterministic timestamps; standalone
    use falls back to ``time.perf_counter``.
    """

    def __init__(self, capacity: int = 1 << 16,
                 clock: Optional[Callable[[], float]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.clock: Callable[[], float] = clock or time.perf_counter
        self._events: deque[TraceEvent] = deque(maxlen=self.capacity)
        self.dropped = 0
        self._ids = itertools.count(1)
        #: Run-level metadata (model/dispatch constants) exported with the
        #: trace — the cost-model replayer reads its byte prices from here.
        self.meta: Dict = {}

    # -- recording --------------------------------------------------------
    def _push(self, ev: TraceEvent) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def instant(self, name: str, cat: str = "", **args) -> None:
        self._push(TraceEvent(self.clock(), "i", name, cat,
                              args=args or None))

    def begin(self, name: str, cat: str = "", **args) -> None:
        self._push(TraceEvent(self.clock(), "B", name, cat,
                              args=args or None))

    def end(self, name: str, cat: str = "", **args) -> None:
        self._push(TraceEvent(self.clock(), "E", name, cat,
                              args=args or None))

    def next_id(self) -> int:
        """Fresh correlation id for an async span (promotion lifecycle)."""
        return next(self._ids)

    def async_begin(self, name: str, span_id: int, cat: str = "",
                    **args) -> None:
        self._push(TraceEvent(self.clock(), "b", name, cat, id=span_id,
                              args=args or None))

    def async_end(self, name: str, span_id: int, cat: str = "",
                  **args) -> None:
        self._push(TraceEvent(self.clock(), "e", name, cat, id=span_id,
                              args=args or None))

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> List[TraceEvent]:
        return list(self._events)

    def instants(self, name: str) -> List[TraceEvent]:
        return [e for e in self._events if e.ph == "i" and e.name == name]

    def spans(self, name: str) -> List[Tuple[TraceEvent, TraceEvent]]:
        """Completed async spans of ``name``, paired by correlation id, in
        begin order. Unmatched begins (still in flight, or whose partner
        fell off the ring) are omitted."""
        begins: Dict[int, TraceEvent] = {}
        out: List[Tuple[TraceEvent, TraceEvent]] = []
        for e in self._events:
            if e.name != name or e.id is None:
                continue
            if e.ph == "b":
                begins[e.id] = e
            elif e.ph == "e" and e.id in begins:
                out.append((begins.pop(e.id), e))
        return out

    # -- export -----------------------------------------------------------
    def to_chrome(self) -> Dict:
        """Chrome trace-event JSON object (perfetto / chrome://tracing).
        Timestamps convert to microseconds; the category doubles as the
        track (pid=0, tid=cat) so each subsystem gets its own lane."""
        evs = []
        for e in self._events:
            d: Dict = {"name": e.name, "ph": e.ph, "cat": e.cat or "misc",
                       "ts": round(e.ts * 1e6, 3), "pid": 0,
                       "tid": e.cat or "misc"}
            if e.id is not None:
                d["id"] = e.id
            if e.args:
                d["args"] = e.args
            evs.append(d)
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": dict(self.meta, dropped_events=self.dropped)}

    def save(self, path: str) -> None:
        """Write the Chrome trace JSON. Deterministic: sorted keys, fixed
        separators, no wall-clock metadata — under the virtual clock two
        identical replays produce byte-identical files."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, sort_keys=True,
                      separators=(",", ":"))
            f.write("\n")


def load_chrome_trace(path: str) -> Dict:
    """Read a trace written by ``FlightRecorder.save`` (or any Chrome
    trace-event JSON object with a ``traceEvents`` list)."""
    with open(path) as f:
        return json.load(f)
