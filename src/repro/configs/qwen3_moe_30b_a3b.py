"""Qwen3-30B-A3B — fine-grained MoE, 128 experts top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B] — the paper's primary evaluation model (Table 3)."""
from repro.models.config import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    vocab_size=151936,
    d_ff=0,  # every layer is MoE; no dense FFN
    attn=AttnConfig(n_heads=32, n_kv_heads=4, head_dim=128,
                    rope_theta=1_000_000.0, qk_norm=True),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768,
                  norm_topk_prob=True),
    norm_eps=1e-6,
    max_seq_len=131072,
    source="hf:Qwen/Qwen3-30B-A3B; paper Table 3",
)
