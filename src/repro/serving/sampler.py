"""Per-request sampling subsystem: parameters, masking, and a counter-based
PRNG that makes every request's output bit-reproducible.

Sampling runs HOST-side on the f32 logits the forward already returns: the
jitted decode graph stays sampling-free (greedy engines compile nothing new)
and the randomness never depends on device, batch shape, or XLA version.

Determinism is the design center. Every random draw for a request is a pure
function of ``(seed, stream, a, b)`` through a counter-based Philox
bit-generator — there is NO sequential RNG state to advance. The draw that
picks a request's t-th token uses counter ``(STREAM_TOKEN, t, 0)``, so the
sampled output is bit-identical no matter which other requests share the
batch, in what order admission happened, or whether the engine replayed the
stream twice (modulo MoE capacity drops, which are compute-batch-dependent —
the same caveat prefix sharing documents). Speculative decoding draws its
accept/residual/bonus uniforms from separate streams keyed by the request's
verify-round counter, so draft bursts never perturb the sequential stream.

``temperature == 0`` is exact greedy: no PRNG is consulted and the token is
``argmax(logits)`` — bit-identical to the pre-sampler engine.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

# PRNG stream ids (the first counter word). One stream per independent use
# so no uniform is ever consumed by two different decisions.
STREAM_TOKEN = 0      # sequential sampling: (t, 0) = t-th emitted token
STREAM_ACCEPT = 1     # spec decode: accept test (round, j)
STREAM_RESIDUAL = 2   # spec decode: rejected-position resample (round, j)
STREAM_BONUS = 3      # spec decode: bonus token after full acceptance


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.

    ``temperature == 0`` ⇒ greedy (top_k/top_p ignored, no randomness).
    ``top_k``: keep only the k highest-probability tokens (None = all).
    ``top_p``: nucleus sampling — keep the smallest probability-sorted set
    whose cumulative mass reaches ``top_p`` (1.0 = all).
    ``seed``: the request's whole entropy source (see module docstring).
    """
    temperature: float = 0.0
    top_k: Optional[int] = None
    top_p: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    def validate(self) -> None:
        """Raise ``ValueError`` on malformed parameters. The engine calls
        this at ``submit()`` so a bad request fails loudly at the door, not
        deep inside a decode round."""
        t = self.temperature
        if not isinstance(t, (int, float)) or isinstance(t, bool) or \
                math.isnan(t) or math.isinf(t) or t < 0:
            raise ValueError(f"temperature must be a finite float >= 0, "
                             f"got {t!r}")
        if not (0.0 < self.top_p <= 1.0) or math.isnan(self.top_p):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p!r}")
        if self.top_k is not None and (not isinstance(self.top_k, int) or
                                       isinstance(self.top_k, bool) or
                                       self.top_k < 1):
            raise ValueError(f"top_k must be a positive int or None, "
                             f"got {self.top_k!r}")
        if not isinstance(self.seed, int) or isinstance(self.seed, bool):
            raise ValueError(f"seed must be an int, got {self.seed!r}")


GREEDY = SamplingParams()


def counter_uniform(seed: int, stream: int, a: int, b: int = 0) -> float:
    """One uniform in [0, 1) as a pure function of ``(seed, stream, a, b)``.

    Philox is a counter-based generator: keying it with the seed and placing
    the coordinates in the counter words gives independent draws with no
    sequential state — any draw can be recomputed in isolation."""
    bg = np.random.Philox(key=np.uint64(seed & (2**64 - 1)),
                          counter=[np.uint64(stream), np.uint64(a),
                                   np.uint64(b), np.uint64(0)])
    return float(np.random.Generator(bg).random())


def sampling_probs(logits: np.ndarray, sp: SamplingParams) -> np.ndarray:
    """Masked, temperature-scaled probabilities over the vocab (f64, sums
    to 1). Order of operations: temperature → softmax → top-k mask → top-p
    mask → renormalize. Requires ``temperature > 0``."""
    if sp.temperature <= 0:
        raise ValueError("sampling_probs needs temperature > 0; greedy "
                         "decoding never builds a distribution")
    z = np.asarray(logits, np.float64) / sp.temperature
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    if sp.top_k is not None and sp.top_k < p.shape[-1]:
        kth = np.partition(p, -sp.top_k)[-sp.top_k]
        p = np.where(p >= kth, p, 0.0)
    if sp.top_p < 1.0:
        # Nucleus: probability-sorted prefix whose cumulative mass first
        # reaches top_p (the token that crosses the threshold is kept).
        order = np.argsort(-p, kind="stable")
        csum = np.cumsum(p[order])
        keep_sorted = np.zeros(p.shape[-1], bool)
        cutoff = int(np.searchsorted(csum, sp.top_p)) + 1
        keep_sorted[:cutoff] = True
        keep = np.zeros_like(keep_sorted)
        keep[order] = keep_sorted
        p = np.where(keep, p, 0.0)
    s = p.sum()
    if s <= 0:                                     # numerically empty mask
        p = np.zeros_like(p)
        p[int(np.argmax(logits))] = 1.0
        return p
    return p / s


def categorical(probs: np.ndarray, u: float) -> int:
    """Inverse-CDF draw: deterministic given (probs, u)."""
    csum = np.cumsum(probs)
    return int(min(np.searchsorted(csum, u * csum[-1], side="right"),
                   probs.shape[-1] - 1))


class RequestSampler:
    """One request's sampling state: the (validated) params plus the two
    counters that key its PRNG streams — the emitted-token index for
    sequential sampling and the speculative-round index for draft bursts.
    Both are derived from the request's own progress, never from batch
    composition, which is what makes outputs reproducible."""

    def __init__(self, sp: Optional[SamplingParams] = None):
        self.sp = sp if sp is not None else GREEDY
        self.spec_round = 0      # bumped once per draft/verify round

    @property
    def greedy(self) -> bool:
        return self.sp.greedy

    def uniform(self, stream: int, a: int, b: int = 0) -> float:
        return counter_uniform(self.sp.seed, stream, a, b)

    def next_token(self, logits: np.ndarray, index: int) -> int:
        """Sample the request's ``index``-th emitted token from one row of
        f32 logits. Greedy params take the exact argmax."""
        if self.sp.greedy:
            return int(np.argmax(logits))
        p = sampling_probs(logits, self.sp)
        return categorical(p, self.uniform(STREAM_TOKEN, index))

    def end_round(self) -> None:
        self.spec_round += 1
