"""Ragged vs padded MoE dispatch: bit-parity at temperature 0 across every
stack kind (full-attention / sliding-window / mamba / jamba; paged + dense
KV), adversarial routing, masked vacant rows, mid-stream residency flips,
spec-decode drafts through the same ragged kernel, per-row capacity
normalization, and the dispatch telemetry gauges.

All engine-level tests run the jnp GEMM backend (the CPU default) so
"ragged vs padded" isolates the LAYOUT — the backends are bit-identical by
the dispatcher parity tests in test_ragged_kernels.py. One end-to-end test
pushes a decode step through the Pallas kernels in interpret mode.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.core.ver import build_bank, publish, unpublish
from repro.models import decode_step, init_caches, init_params
from repro.models.config import MoEConfig
from repro.models.moe import init_moe, moe_apply, moe_capacity
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           make_backend, make_prompts)

ARCHS = {}


def _setup_arch(arch):
    """Reduced config + params. ``granite-moe-1b-a400m+sw`` is the granite
    MoE stack with a sliding-window ring cache — no stock arch combines
    sliding-window attention with MoE FFNs outside jamba's mixed stack, and
    the ring-slot layout is exactly what the ragged layout must not care
    about."""
    if arch not in ARCHS:
        base = arch.split("+")[0]
        cfg = get_config(base, reduced=True)
        if arch.endswith("+sw"):
            cfg = dataclasses.replace(
                cfg, attn=dataclasses.replace(cfg.attn, sliding_window=32))
        ARCHS[arch] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    cfg, params = ARCHS[arch]
    return cfg, jax.tree_util.tree_map(lambda x: x, params)


# ---------------------------------------------------------------------------
# moe_apply level: layouts agree bit for bit
# ---------------------------------------------------------------------------

def _moe_setup(E=8, d=128, f=256, T=24, k=2, n_hi=2, seed=0, published=()):
    cfg = MoEConfig(num_experts=E, top_k=k, d_ff_expert=f,
                    norm_topk_prob=True)
    params = init_moe(jax.random.PRNGKey(seed), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, d), jnp.bfloat16)
    w = {n: a[None] for n, a in params["experts"].items()}
    bank = build_bank(w, n_hi=n_hi, lo_bits=4)
    for slot, e in enumerate(published):
        bank.slot_map = bank.slot_map.at[0, e].set(slot)
        bank.slot_owner = bank.slot_owner.at[0, slot].set(e)
        for n in bank.hi:
            bank.hi[n] = bank.hi[n].at[0, slot].set(w[n][0, e])
    return cfg, params, x, jax.tree_util.tree_map(lambda a: a[0], bank)


def _both(params, bank, x, cfg, cap, **kw):
    yp, ap = moe_apply(params, bank, x, cfg, cap, dispatch="padded", **kw)
    yr, ar = moe_apply(params, bank, x, cfg, cap, dispatch="ragged", **kw)
    return yp, yr, ap, ar


def test_ragged_matches_padded_bitwise_mixed_precision():
    cfg, params, x, bank = _moe_setup(published=(1, 5))
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    yp, yr, ap, ar = _both(params, bank, x, cfg, cap)
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(ap.counts),
                                  np.asarray(ar.counts))
    assert float(ap.dropped) == float(ar.dropped) == 0.0


def test_ragged_matches_padded_under_capacity_drops():
    cfg, params, x, bank = _moe_setup(T=64)
    yp, yr, ap, ar = _both(params, bank, x, cfg, 4)
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(yr))
    assert float(ap.dropped) == float(ar.dropped) > 0.0


def test_ragged_adversarial_all_tokens_one_expert():
    """Max-imbalance routing: every token's top-1 lands on one expert —
    the layout degenerates to a single dense segment and still matches."""
    cfg, params, x, bank = _moe_setup(k=1)
    # All-zero router ⇒ uniform probs ⇒ top-1 deterministically picks
    # expert 0 for EVERY token.
    params["router"] = jnp.zeros_like(params["router"])
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    yp, yr, _, ar = _both(params, bank, x, cfg, cap)
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(yr))
    assert int(ar.active_experts) == 1


def test_ragged_masked_vacant_rows():
    """token_valid-masked rows (vacant continuous-batching slots) vanish
    from dispatch under both layouts; real rows stay bit-identical."""
    cfg, params, x, bank = _moe_setup(published=(2,))
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    tv = jnp.arange(x.shape[0]) % 3 != 1
    yp, yr, ap, ar = _both(params, bank, x, cfg, cap, token_valid=tv,
                           n_rows=x.shape[0])
    mask = np.asarray(tv)
    np.testing.assert_array_equal(np.asarray(yp)[mask], np.asarray(yr)[mask])
    np.testing.assert_array_equal(np.asarray(ap.row_counts),
                                  np.asarray(ar.row_counts))
    assert np.asarray(ar.row_counts)[~mask].sum() == 0


def test_ragged_follows_promotion_demotion_flips():
    """Mid-stream residency changes: publish/unpublish between calls; the
    ragged slot derivation (via slot_owner, the stable handles) tracks
    every flip bit-identically with the padded overlay."""
    cfg, params, x, bank = _moe_setup(n_hi=2)
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    w = {n: a[None] for n, a in params["experts"].items()}

    def check():
        yp, yr, _, _ = _both(params, bank, x, cfg, cap)
        np.testing.assert_array_equal(np.asarray(yp), np.asarray(yr))
        return np.asarray(yp)

    y0 = check()
    # promote expert 4 → slot 0 (write weights first, then publish)
    for n in bank.hi:
        bank.hi[n] = bank.hi[n].at[0].set(w[n][0, 4])
    sm, so = publish(bank.slot_map[None], bank.slot_owner[None],
                     jnp.int32(0), jnp.int32(4), jnp.int32(0))
    bank.slot_map, bank.slot_owner = sm[0], so[0]
    y1 = check()
    assert not np.array_equal(y0, y1)          # hi weights genuinely used
    # demote it again (unpublish: handle → lo, slot freed)
    sm, so = unpublish(bank.slot_map[None], bank.slot_owner[None],
                       jnp.int32(0), jnp.int32(4))
    bank.slot_map, bank.slot_owner = sm[0], so[0]
    y2 = check()
    np.testing.assert_array_equal(y0, y2)      # flip is fully reversible


def test_all_lo_draft_bank_is_all_lo_under_ragged():
    """The spec-draft derivation (slot_owner := −1 everywhere, slot_map
    untouched) must read as all-lo under the ragged slot derivation too —
    the property that lets drafts reuse the same kernel with zero extra
    weights."""
    cfg, params, x, bank = _moe_setup(published=(1, 5))
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    draft = dataclasses.replace(
        bank, slot_owner=jnp.full_like(bank.slot_owner, -1))
    nohi = dataclasses.replace(
        draft, slot_map=jnp.full_like(bank.slot_map, -1))
    y_draft, _ = moe_apply(params, draft, x, cfg, cap, dispatch="ragged")
    y_nohi, _ = moe_apply(params, nohi, x, cfg, cap, dispatch="ragged")
    np.testing.assert_array_equal(np.asarray(y_draft), np.asarray(y_nohi))


def test_dense_bank_ragged_matches_padded():
    """bf16 dict banks (fp16 / offload backends) ride the same ragged
    compaction — no quantized tier anywhere — and must match the padded
    overlay bit for bit, including masked vacant rows and row_counts."""
    cfg, params, x, _ = _moe_setup()
    dense = dict(params["experts"])
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    yp, ap = moe_apply(params, dense, x, cfg, cap, dispatch="padded")
    yr, ar = moe_apply(params, dense, x, cfg, cap, dispatch="ragged")
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(ap.counts),
                                  np.asarray(ar.counts))
    tv = jnp.arange(x.shape[0]) % 3 != 1
    yp, ap = moe_apply(params, dense, x, cfg, cap, token_valid=tv,
                       n_rows=x.shape[0], dispatch="padded")
    yr, ar = moe_apply(params, dense, x, cfg, cap, token_valid=tv,
                       n_rows=x.shape[0], dispatch="ragged")
    mask = np.asarray(tv)
    np.testing.assert_array_equal(np.asarray(yp)[mask], np.asarray(yr)[mask])
    np.testing.assert_array_equal(np.asarray(ap.row_counts),
                                  np.asarray(ar.row_counts))


@pytest.mark.parametrize("name", ["fp16", "offload"])
def test_engine_token_identity_dense_backends_ragged(name):
    """The dense-bank backends serve token-identically under ragged vs
    padded dispatch (the ragged layout is bank-agnostic end to end)."""
    def backend():
        if name == "offload":
            from repro.serving import OffloadConfig
            return make_backend("offload", ocfg=OffloadConfig(
                cache_experts_per_layer=4))
        return make_backend("fp16")

    tp, _ = _tokens("granite-moe-1b-a400m", "padded", True, backend=backend)
    tr, eng = _tokens("granite-moe-1b-a400m", "ragged", True, backend=backend)
    assert tp == tr
    assert eng.stats()["active_experts"] > 0


def test_moe_aux_dispatch_telemetry():
    cfg, params, x, bank = _moe_setup()
    cap = moe_capacity(x.shape[0], cfg, 8.0)
    _, _, ap, ar = _both(params, bank, x, cfg, cap)
    n_act = int((np.asarray(ar.counts) > 0).sum())
    assert int(ap.active_experts) == int(ar.active_experts) == n_act
    # padded pads (E·C − kept) rows; ragged only intra-tile slack — with
    # ample capacity the ragged ratio is strictly smaller.
    assert 0.0 <= float(ar.dispatch_pad_ratio) < float(
        ap.dispatch_pad_ratio) <= 1.0


# ---------------------------------------------------------------------------
# Per-row capacity normalization
# ---------------------------------------------------------------------------

def test_row_capacity_makes_decode_drops_batch_shape_independent():
    """Tight capacity at a crowded decode batch drops assignments the solo
    run would keep (the ROADMAP caveat). With ``row_capacity`` the kept set
    depends only on each row's own routing — row 0 computes bit-identically
    solo and crowded — under BOTH layouts."""
    cfg, params, x, bank = _moe_setup(E=4, T=32, k=2)
    # Teeth: under the GLOBAL capacity rule drops hit high-rank
    # assignments, i.e. late rows of a crowded batch — the last row
    # computes differently crowded vs solo.
    tight = 4
    y_crowd, aux = moe_apply(params, bank, x, cfg, tight, dispatch="padded")
    y_solo, _ = moe_apply(params, bank, x[-1:], cfg,
                          moe_capacity(1, cfg, 2.0), dispatch="padded")
    assert float(aux.dropped) > 0.0
    assert not np.array_equal(np.asarray(y_solo[0]), np.asarray(y_crowd[-1]))

    rc = moe_capacity(1, cfg, 2.0)
    for dispatch in ("padded", "ragged"):
        ys, _ = moe_apply(params, bank, x[-1:], cfg, 0, n_rows=1,
                          row_capacity=rc, dispatch=dispatch)
        yc, _ = moe_apply(params, bank, x, cfg, 0, n_rows=32,
                          row_capacity=rc, dispatch=dispatch)
        np.testing.assert_array_equal(np.asarray(ys[0]), np.asarray(yc[-1]))


def test_row_capacity_drop_rule_is_per_row():
    """A row routing more than row_capacity tokens to one expert drops the
    excess; other rows' identical routing is untouched."""
    cfg, params, x, bank = _moe_setup(E=4, T=16, k=2)
    _, aux = moe_apply(params, bank, x, cfg, 0, n_rows=4, row_capacity=1,
                       dispatch="padded")
    # 4 tokens/row × top-2 = 8 assignments over ≤4 experts per row ⇒ at
    # least half must drop under row_capacity=1... exactly: kept ≤ 4/row.
    assert float(aux.dropped) > 0.0
    _, aux2 = moe_apply(params, bank, x, cfg, 0, n_rows=4, row_capacity=8,
                        dispatch="padded")
    assert float(aux2.dropped) == 0.0


def test_row_capacity_engine_solo_vs_crowded_token_identity():
    cfg, params = _setup_arch("granite-moe-1b-a400m")
    prompt = make_prompts("text", cfg.vocab_size, 1, 24, seed=3)[0]
    fillers = [make_prompts("text", cfg.vocab_size, 1, 24, seed=50 + i)[0]
               for i in range(3)]

    def run(crowd):
        _, p = _setup_arch("granite-moe-1b-a400m")
        eng = InferenceEngine(
            cfg, p, make_backend("static", lo_bits=4),
            EngineConfig(max_slots=4, max_len=96, capacity_factor=1.0,
                         paged=True, row_capacity_norm=True))
        h = eng.submit(Request(tokens=prompt, max_new_tokens=8))
        if crowd:
            for f in fillers:
                eng.submit(Request(tokens=f, max_new_tokens=8))
        eng.drain()
        return h.tokens

    assert run(False) == run(True)


# ---------------------------------------------------------------------------
# Engine level: token identity across every stack kind, paged + dense
# ---------------------------------------------------------------------------

def _serve(cfg, eng, lengths=(24, 17, 21), new=8, seed=7):
    handles = [eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, L, seed=seed + s)[0],
        max_new_tokens=new))
        for s, L in enumerate(lengths)]
    eng.drain()
    return [h.tokens for h in handles]


def _tokens(arch, dispatch, paged, spec_k=0, backend=None, **ecfg_kw):
    cfg, params = _setup_arch(arch)
    be = make_backend("static", lo_bits=4) if backend is None else backend()
    eng = InferenceEngine(
        cfg, params, be,
        EngineConfig(max_slots=2, max_len=96, capacity_factor=8.0,
                     paged=paged, spec_k=spec_k, moe_dispatch=dispatch,
                     **ecfg_kw))
    toks = _serve(cfg, eng)
    return toks, eng


@pytest.mark.parametrize("arch,paged", [
    ("granite-moe-1b-a400m", True),      # full attention, paged pool
    ("granite-moe-1b-a400m", False),     # full attention, dense rows
    ("granite-moe-1b-a400m+sw", True),   # sliding-window ring, paged
    ("granite-moe-1b-a400m+sw", False),  # sliding-window ring, dense
    ("jamba-v0_1-52b", True),            # mamba + sliding attn, paged
    ("jamba-v0_1-52b", False),           # mamba + sliding attn, dense
])
def test_engine_token_identity_ragged_vs_padded(arch, paged):
    tp, _ = _tokens(arch, "padded", paged)
    tr, eng = _tokens(arch, "ragged", paged)
    assert tp == tr
    st = eng.stats()
    assert st["active_experts"] > 0
    assert 0.0 <= st["dispatch_pad_ratio"] <= 1.0


def test_engine_token_identity_mixed_precision_target():
    """Frozen warmed DynaExq bank (hi tier genuinely populated): ragged
    selects hi/lo per tile in-kernel and still matches padded exactly."""
    def backend():
        return make_backend("dynaexq", lo_bits=4, n_hi_per_layer=2,
                            controller=ControllerConfig(
                                update_interval_s=0.0))

    def build(dispatch):
        cfg, params = _setup_arch("granite-moe-1b-a400m")
        eng = InferenceEngine(
            cfg, params, backend(),
            EngineConfig(max_slots=2, max_len=96, capacity_factor=8.0,
                         paged=True, moe_dispatch=dispatch))
        warm = make_prompts("text", cfg.vocab_size, 2, 16, seed=99)
        eng.generate({"tokens": warm}, 4)
        eng.backend.force_update()
        eng.backend.flush()
        for ctl in eng.backend.controllers.values():
            ctl.cfg = dataclasses.replace(ctl.cfg, update_interval_s=1e9)
        assert any((np.asarray(b.slot_owner) >= 0).any()
                   for b in eng.banks.values())    # hi tier genuinely hot
        return cfg, eng

    cfg, ep = build("padded")
    tp = _serve(cfg, ep, lengths=(20, 13))
    cfg, er = build("ragged")
    tr = _serve(cfg, er, lengths=(20, 13))
    assert tp == tr


@pytest.mark.parametrize("arch,paged", [
    ("granite-moe-1b-a400m", True),
    ("granite-moe-1b-a400m+sw", False),
    ("jamba-v0_1-52b", True),
])
def test_spec_decode_draft_rides_ragged_kernel(arch, paged):
    """Speculative rounds (all-lo drafts + mixed verify) under the ragged
    layout: token-identical to the padded spec engine AND to the
    non-speculative engine — the draft path routes through the same ragged
    kernel, no separate all-lo GEMM."""
    t_plain, _ = _tokens(arch, "ragged", paged, spec_k=0)
    t_spec_p, _ = _tokens(arch, "padded", paged, spec_k=4)
    t_spec_r, eng = _tokens(arch, "ragged", paged, spec_k=4)
    assert t_spec_r == t_spec_p == t_plain
    assert eng.stats()["spec_rounds"] > 0


def test_engine_decode_through_pallas_interpret(monkeypatch):
    """One decode step end to end with the ragged Pallas kernels in
    interpret mode (CI pins this: the kernel code path, not the jnp
    fallback, under a real stack). Un-jitted direct call so the env switch
    is read at trace time."""
    monkeypatch.setenv("REPRO_MOE_GEMM", "pallas")
    cfg, params = _setup_arch("granite-moe-1b-a400m")
    sb = cfg.superblock_or_default()
    banks = {}
    for pos in range(len(sb)):
        if cfg.ffn_kind(pos) == "moe":
            experts = params["blocks"][str(pos)]["moe"]["experts"]
            banks[str(pos)] = build_bank(experts, n_hi=1, lo_bits=4)
    caches = init_caches(cfg, 2, 32)
    tok = jnp.asarray([3, 5], jnp.int32)
    logits_p, _, _ = decode_step(params, cfg, tok, jnp.int32(0), caches,
                                 bank=banks, moe_dispatch="ragged")
    monkeypatch.setenv("REPRO_MOE_GEMM", "jnp")
    logits_j, _, _ = decode_step(params, cfg, tok, jnp.int32(0), caches,
                                 bank=banks, moe_dispatch="ragged")
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_j),
                               rtol=2e-2, atol=2e-1)
