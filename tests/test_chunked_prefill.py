"""Chunked prefill parity: splitting a long prompt into block-aligned
chunks interleaved with decode must be bit-identical to the single-shot
prefill — same tokens, same KV bytes — and must not widen the prefill
compile surface beyond the existing bucket ladder."""
import jax
import numpy as np
import pytest

from repro.serving import (EngineConfig, InferenceEngine, Request,
                           RequestState, SchedulerConfig, make_backend,
                           make_prompts)

PLEN, CHUNK, MAXLEN = 48, 32, 96


def _engine(cfg, params, *, chunk, sharing=True, max_slots=2):
    clone = jax.tree_util.tree_map(lambda x: x, params)
    return InferenceEngine(
        cfg, clone, make_backend("fp16"),
        EngineConfig(max_slots=max_slots, max_len=MAXLEN,
                     prefix_sharing=sharing,
                     scheduler=SchedulerConfig(prefill_chunk=chunk)))


def _submit(eng, cfg, plen=PLEN, max_new=8, seed=5):
    return eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, plen, seed=seed)[0],
        max_new_tokens=max_new))


@pytest.mark.parametrize("sharing", [True, False])
def test_chunked_token_parity(serving_setup, sharing):
    cfg, params = serving_setup
    ref = _engine(cfg, params, chunk=0, sharing=sharing)
    h0 = _submit(ref, cfg)
    ref.drain()
    assert ref.counters["chunk_prefills"] == 0

    eng = _engine(cfg, params, chunk=CHUNK, sharing=sharing)
    assert eng._chunk_tokens == CHUNK
    h1 = _submit(eng, cfg)
    eng.drain()
    # 48-token prompt at chunk 32 → two chunk forwards (32 + 16).
    assert eng.counters["chunk_prefills"] == 2
    assert h1.tokens == h0.tokens
    eng.pool.check_invariants()


def test_chunked_kv_bit_exact(serving_setup):
    """After the first emitted token, every prompt KV lane written by the
    chunked path equals the single-shot path's, position by position
    (compared through each engine's own lease table)."""
    cfg, params = serving_setup

    def run_until_first_token(chunk):
        eng = _engine(cfg, params, chunk=chunk, sharing=False)
        h = _submit(eng, cfg, max_new=4)
        for _ in range(32):
            if h.tokens:
                break
            eng.step()
        assert h.tokens and h.lease is not None
        return eng, h

    ref, h0 = run_until_first_token(0)
    eng, h1 = run_until_first_token(CHUNK)
    bt = ref._bt
    for p in ref._attn_pos:
        a, b = ref.caches.blocks[p], eng.caches.blocks[p]
        for pos in range(PLEN):
            j, off = pos // bt, pos % bt
            pa, pb = int(h0.lease.table[j]), int(h1.lease.table[j])
            assert pa >= 0 and pb >= 0
            for name in ("k", "v"):
                la = np.asarray(getattr(a, name))[:, pa, :, off]
                lb = np.asarray(getattr(b, name))[:, pb, :, off]
                np.testing.assert_array_equal(
                    la, lb, err_msg=f"layer {p} {name} pos {pos}")


def test_chunked_compile_surface(serving_setup):
    """Chunk forwards reuse ladder-bucket shapes only: a second chunked
    engine re-running the same workload adds ZERO new paged-prefill
    compiles, and every traced shape is an existing ladder bucket."""
    from repro.serving.engine import _prefill_paged_jit
    cfg, params = serving_setup
    eng = _engine(cfg, params, chunk=CHUNK)
    _submit(eng, cfg)
    eng.drain()
    assert all(b in eng.buckets for _, b in eng.prefill_shapes)
    n0 = _prefill_paged_jit._cache_size()
    eng2 = _engine(cfg, params, chunk=CHUNK)
    _submit(eng2, cfg)
    eng2.drain()
    assert _prefill_paged_jit._cache_size() == n0


def test_chunked_interleaves_with_decode(serving_setup):
    """A running neighbor keeps decoding in the very steps that advance
    another request's chunked prefill."""
    cfg, params = serving_setup
    eng = _engine(cfg, params, chunk=CHUNK)
    short = eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, 8, seed=1)[0],
        max_new_tokens=24))
    eng.step()
    assert short.state is RequestState.RUNNING
    longh = _submit(eng, cfg, max_new=4)
    saw_overlap = False
    for _ in range(64):
        before = len(short.tokens)
        eng.step()
        if (longh.state is RequestState.PREFILLING
                and len(short.tokens) > before):
            saw_overlap = True
        if longh.state.value == "finished" and \
                short.state.value == "finished":
            break
    assert saw_overlap, "decode stalled behind the chunked prefill"
    assert eng.counters["chunk_prefills"] >= 1

    # Parity against a solo single-shot run of the same long request.
    ref = _engine(cfg, params, chunk=0)
    h0 = _submit(ref, cfg, max_new=4)
    ref.drain()
    assert longh.tokens == h0.tokens


def test_chunking_disabled_for_mamba_and_small_knob(serving_setup):
    from repro.configs import get_config
    from repro.models import init_params
    cfg, params = serving_setup
    # Knob below the smallest block-aligned bucket → silently off.
    eng = _engine(cfg, params, chunk=8)
    assert eng._chunk_tokens == 0
    # Mamba stacks must prefill in one shot (SSD takes no initial state).
    jcfg = get_config("jamba-v0_1-52b", reduced=True)
    jparams = init_params(jax.random.PRNGKey(0), jcfg)
    jeng = _engine(jcfg, jparams, chunk=CHUNK)
    assert jeng._chunk_tokens == 0
    h = _submit(jeng, jcfg, max_new=4)
    jeng.drain()
    assert jeng.counters["chunk_prefills"] == 0
    assert len(h.tokens) == 4
