"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`; the
model builder in ``models/model.py`` consumes only this dataclass, so new
architectures are added by writing a config, not new model code.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # None = full causal
    use_rope: bool = True                  # whisper uses learned positions
    qk_norm: bool = False                  # qwen3-style per-head RMSNorm on q/k

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 2.0           # train/smoke; serving uses its own
    norm_topk_prob: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    d_ff: int = 0                # dense-MLP hidden dim (0 = no dense MLP)
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid stacks: per-position block kinds within a repeating super-block,
    # e.g. jamba = ('mamba',)*7 + ('attn',) with MoE on odd positions.
    superblock: Tuple[str, ...] = ()
    moe_positions: Tuple[int, ...] = ()    # super-block positions using MoE FFN
    # Encoder-decoder (audio).
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frontend frames
    # VLM.
    num_image_tokens: int = 0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    source: str = ""

    # ---- derived ------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def uses_attention(self) -> bool:
        return self.attn is not None

    def superblock_or_default(self) -> Tuple[str, ...]:
        """Layer-kind pattern of one repeating super-block."""
        if self.superblock:
            return self.superblock
        if self.family == "ssm":
            return ("mamba",)
        return ("attn",)

    def n_superblocks(self) -> int:
        sb = self.superblock_or_default()
        if self.n_layers % len(sb):
            raise ValueError(f"{self.name}: n_layers={self.n_layers} not a "
                             f"multiple of super-block {len(sb)}")
        return self.n_layers // len(sb)

    def ffn_kind(self, pos_in_superblock: int) -> str:
        """'moe' or 'dense' for the FFN at this super-block position."""
        if self.moe is None:
            return "dense"
        if not self.moe_positions:          # pure-MoE stacks: every layer
            return "moe"
        return "moe" if pos_in_superblock in self.moe_positions else "dense"

    # ---- parameter accounting (for 6ND roofline terms) ----------------
    def param_count(self) -> int:
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        return _count_params(self, active_only=True)

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                num_experts: int = 4, vocab: int = 512,
                max_seq_len: int = 1024) -> "ArchConfig":
        """Smoke-test variant of the same family (per assignment rules)."""
        d_model = min(d_model, 512)
        attn = self.attn
        if attn is not None:
            n_heads = max(2, min(attn.n_heads, 4))
            n_kv = max(1, min(attn.n_kv_heads, n_heads))
            attn = dataclasses.replace(
                attn, n_heads=n_heads, n_kv_heads=n_kv,
                head_dim=min(attn.head_dim, 64),
                sliding_window=(64 if attn.sliding_window else None))
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, num_experts=min(moe.num_experts, num_experts),
                top_k=min(moe.top_k, 2),
                d_ff_expert=min(moe.d_ff_expert, 2 * d_model),
                d_ff_shared=min(moe.d_ff_shared, d_model) if moe.n_shared_experts else 0)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, d_state=min(ssm.d_state, 32),
                                      head_dim=32, chunk=16)
        sb = self.superblock_or_default()
        n_layers = max(n_layers, len(sb)) if self.superblock else n_layers
        if self.superblock and n_layers % len(sb):
            n_layers = len(sb)
        return dataclasses.replace(
            self, name=self.name + "-smoke", n_layers=n_layers,
            d_model=d_model, vocab_size=vocab,
            d_ff=min(self.d_ff, 2 * d_model) if self.d_ff else 0,
            attn=attn, moe=moe, ssm=ssm,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64) if self.encoder_seq else 0,
            num_image_tokens=min(self.num_image_tokens, 16) if self.num_image_tokens else 0,
            max_seq_len=max_seq_len)


def _count_params(cfg: ArchConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    sb = cfg.superblock_or_default()
    per_sb = 0
    for pos, kind in enumerate(sb):
        per_sb += 2 * d  # pre-norms
        if kind == "attn" and cfg.attn is not None:
            a = cfg.attn
            per_sb += d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
        elif kind == "mamba" and cfg.ssm is not None:
            s = cfg.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            conv_dim = di + 2 * s.n_groups * s.d_state
            per_sb += d * (2 * di + 2 * s.n_groups * s.d_state + nh)
            per_sb += conv_dim * s.d_conv + 3 * nh + di  # conv, A/D/dt, gate-norm
            per_sb += di * d
        if cfg.ffn_kind(pos) == "moe":
            m = cfg.moe
            per_sb += d * m.num_experts  # router
            e = m.num_experts if not active_only else m.top_k
            per_sb += e * 3 * d * m.d_ff_expert
            if m.n_shared_experts:
                per_sb += m.n_shared_experts * 3 * d * m.d_ff_shared
        elif cfg.d_ff:
            per_sb += 3 * d * cfg.d_ff
    total += per_sb * cfg.n_superblocks()
    if cfg.is_encoder_decoder and cfg.attn is not None:
        a = cfg.attn
        enc_layer = 2 * d + d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d + 3 * d * cfg.d_ff
        cross = d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d + d
        total += cfg.n_encoder_layers * enc_layer + cfg.n_layers * cross
    return int(total)
