"""Synthetic request workloads with controllable routing skew & shift.

The paper's Fig. 2 shows the hot expert set is disjoint across text / math /
code workloads. We reproduce the *mechanism* without real datasets: each
workload draws tokens Zipf-distributed over a workload-specific slice of the
vocabulary. Different input statistics → different embedding clusters →
different router hot sets (measured, not assumed — see
benchmarks/workload_shift.py).
"""
from __future__ import annotations

import numpy as np

WORKLOADS = ("text", "math", "code")


def _zipf_probs(n: int, s: float = 1.2) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** s
    return p / p.sum()


def make_prompts(workload: str, vocab_size: int, batch: int, length: int,
                 seed: int = 0) -> np.ndarray:
    """(batch, length) int32 token ids for one workload."""
    if workload not in WORKLOADS:
        raise KeyError(f"unknown workload {workload!r}")
    wi = WORKLOADS.index(workload)
    rng = np.random.default_rng(seed + 1000 * wi)
    # Each workload occupies a third of the vocab, shuffled so slices are not
    # trivially ordered; heavy-tailed within the slice.
    perm = np.random.default_rng(42).permutation(vocab_size)
    lo = wi * vocab_size // 3
    hi = (wi + 1) * vocab_size // 3
    slice_ids = perm[lo:hi]
    probs = _zipf_probs(len(slice_ids))
    draws = rng.choice(len(slice_ids), size=(batch, length), p=probs)
    return slice_ids[draws].astype(np.int32)


def mixed_stream(vocab_size: int, batch: int, length: int, phases,
                 seed: int = 0):
    """Yield (workload_name, prompts) per phase — the shifting serving mix."""
    for i, (workload, n_batches) in enumerate(phases):
        for j in range(n_batches):
            yield workload, make_prompts(workload, vocab_size, batch, length,
                                         seed=seed + 17 * i + j)
