"""Versioned Expert Residency (VER) — paper §3.2, adapted to JAX/TPU.

The paper's pointer-indirection handle table becomes two small device arrays:

* ``slot_map[L, E]``  : expert → hi-pool slot (−1 ⇒ lo fallback). This is the
  "stable handle": the MoE kernel always gathers through it, so *publishing*
  a new version is a single int32 store, and the forward pass always sees a
  fully-materialized version (the hi slot is only referenced after its weight
  copy completed — publish-then-switch).
* ``slot_owner[L, n_hi]`` : hi slot → expert id (−1 ⇒ free). Used by the
  weight-scatter formulation (jnp path) and by eviction.

Weight versions live in two preallocated pools (paper §3.3):

* lo pool  — packed int4/int2 ``QuantizedTensor``s for ALL experts, always
  resident (the guaranteed fallback).
* hi pool  — ``n_hi`` bf16 (or higher-bit) expert slots per layer. Fixed
  granularity = one expert ⇒ no fragmentation by construction.

Residency states (host-side mirror, per expert): RESIDENT_LO, PROMOTING,
RESIDENT_HI, DEMOTING. The device arrays only ever reflect *published*
states; PROMOTING/DEMOTING exist host-side while a transition is in flight.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.qtensor import QuantizedTensor, quantize, quantized_nbytes


class Residency(enum.Enum):
    RESIDENT_LO = 0
    PROMOTING = 1
    RESIDENT_HI = 2
    DEMOTING = 3
    EVICTING = 4


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class ExpertBankQ:
    """Mixed-precision expert bank for one MoE stack (all layers, stacked).

    ``lo``: dict name → QuantizedTensor with leading dims (L, E, …).
    ``hi``: dict name → bf16 array with leading dims (L, n_hi, …).
    ``slot_owner``: (L, n_hi) int32, −1 = free slot.
    ``slot_map``: (L, E) int32, −1 = serve from lo pool.
    """

    lo: Dict[str, QuantizedTensor]
    hi: Dict[str, jax.Array]
    slot_owner: jax.Array
    slot_map: jax.Array

    def tree_flatten_with_keys(self):
        lo_names = tuple(sorted(self.lo))
        hi_names = tuple(sorted(self.hi))
        K = jax.tree_util.GetAttrKey
        children = tuple((K(f"lo.{n}"), self.lo[n]) for n in lo_names) + \
            tuple((K(f"hi.{n}"), self.hi[n]) for n in hi_names) + \
            ((K("slot_owner"), self.slot_owner), (K("slot_map"), self.slot_map))
        return children, (lo_names, hi_names)

    def tree_flatten(self):
        children, aux = self.tree_flatten_with_keys()
        return tuple(c for _, c in children), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        lo_names, hi_names = aux
        nl, nh = len(lo_names), len(hi_names)
        lo = dict(zip(lo_names, children[:nl]))
        hi = dict(zip(hi_names, children[nl:nl + nh]))
        slot_owner, slot_map = children[nl + nh:]
        return cls(lo=lo, hi=hi, slot_owner=slot_owner, slot_map=slot_map)

    @property
    def n_hi(self) -> int:
        return self.slot_owner.shape[-1]

    @property
    def num_experts(self) -> int:
        return self.slot_map.shape[-1]


def build_bank(expert_weights: Dict[str, jax.Array], n_hi: int,
               lo_bits: int, group_size: int = 64,
               hi_bits: int = 16) -> ExpertBankQ:
    """Prepare the two weight tiers from dense bf16 expert weights.

    ``expert_weights``: name → (L, E, K, N). The hi pool starts EMPTY
    (all experts serve from lo) — the online policy fills it.

    ``hi_bits``: 16 keeps bf16 hi versions (paper's FP16 tier). A value in
    {4, 8} builds an int-hi tier (the paper's Qwen3-80B Int4-hi/Int2-lo
    configuration); those are stored dequantized in the pool (pool bytes are
    then accounted at ``hi_bits`` by the budget model, matching a real
    deployment where the pool stores packed int4).
    """
    names = sorted(expert_weights)
    first = expert_weights[names[0]]
    L = first.shape[0]
    E = first.shape[1]
    lo, hi = {}, {}
    for n in names:
        w = expert_weights[n]
        lo[n] = quantize(w, bits=lo_bits, group_size=group_size)
        if hi_bits < 16:
            # Simulate the int-hi tier numerically (store its dequantized
            # values); budget accounting uses hi_bits.
            w = quantize(w, bits=hi_bits, group_size=group_size).dequantize()
        hi[n] = jnp.zeros((L, n_hi) + w.shape[2:], jnp.bfloat16)
    slot_owner = jnp.full((L, n_hi), -1, jnp.int32)
    slot_map = jnp.full((L, E), -1, jnp.int32)
    return ExpertBankQ(lo=lo, hi=hi, slot_owner=slot_owner, slot_map=slot_map)


def build_bank_empty(expert_weights_shapes: Dict[str, tuple], n_hi: int,
                     lo_bits: int, group_size: int = 64) -> ExpertBankQ:
    """A bank whose lo rows are NOT yet materialized (streaming cold start):
    packed codes and scales are zero until ``write_lo_expert`` stages each
    expert's rows from the checkpoint shards. Callers gate serving on the
    store's ``lo_valid`` mask — a forward pass must never read a zero row.

    ``expert_weights_shapes``: name → (L, E, K, N) logical dense shapes."""
    from repro.quant.qtensor import _elems_per_byte   # layout contract
    lo, hi = {}, {}
    first = next(iter(expert_weights_shapes.values()))
    L, E = first[0], first[1]
    for n, shape in sorted(expert_weights_shapes.items()):
        l4, e4, k, nn = shape
        lo[n] = QuantizedTensor(
            packed=jnp.zeros((l4, e4, k // _elems_per_byte(lo_bits), nn),
                             jnp.uint8),
            scales=jnp.zeros((l4, e4, k // group_size, nn), jnp.bfloat16),
            bits=lo_bits, group_size=group_size, shape=tuple(shape))
        hi[n] = jnp.zeros((l4, n_hi, k, nn), jnp.bfloat16)
    slot_owner = jnp.full((L, n_hi), -1, jnp.int32)
    slot_map = jnp.full((L, E), -1, jnp.int32)
    return ExpertBankQ(lo=lo, hi=hi, slot_owner=slot_owner,
                       slot_map=slot_map)


def expert_hi_nbytes(expert_weights_shapes: Dict[str, tuple], hi_bits: int = 16,
                     group_size: int = 64) -> int:
    """Device bytes of ONE expert's hi-precision version (per layer)."""
    total = 0
    for shape in expert_weights_shapes.values():
        per = shape[2:]  # (K, N)
        if hi_bits >= 16:
            total += int(np.prod(per)) * 2
        else:
            total += quantized_nbytes(per, hi_bits, group_size)
    return total


def expert_lo_nbytes(expert_weights_shapes: Dict[str, tuple], lo_bits: int,
                     group_size: int = 64) -> int:
    total = 0
    for shape in expert_weights_shapes.values():
        total += quantized_nbytes(shape[2:], lo_bits, group_size)
    return total


# ---------------------------------------------------------------------------
# Published-state updates. These are the ONLY functions that touch the device
# arrays; both are donated in the jitted controller path so promotion writes
# happen in place (the TPU analogue of copying into a preallocated pool slot).
# ---------------------------------------------------------------------------

@jax.jit
def write_hi_slot(hi_leaf: jax.Array, layer: jax.Array, slot: jax.Array,
                  w: jax.Array) -> jax.Array:
    """Copy one expert's hi weights into pool slot (layer, slot).

    This is the 'async copy on stream_mig': the serve step in flight does not
    depend on this buffer (the slot is unpublished), so XLA is free to overlap
    it with compute.
    """
    return jax.lax.dynamic_update_slice(
        hi_leaf, w[None, None], (layer, slot) + (0,) * (w.ndim))


@jax.jit
def write_lo_expert(leaf: jax.Array, layer: jax.Array, expert: jax.Array,
                    row: jax.Array) -> jax.Array:
    """Copy one expert's lo-tier rows (packed codes OR scales) into an
    (L, E, …) bank leaf — the H2D staging write of host→lo promotion and
    streaming cold start. Same publish-then-switch discipline as
    ``write_hi_slot``: the row is unreferenced until its residency mask
    flips, so XLA overlaps the copy with in-flight serve steps."""
    return jax.lax.dynamic_update_slice(
        leaf, row.astype(leaf.dtype)[None, None],
        (layer, expert) + (0,) * row.ndim)


@jax.jit
def write_lo_rows(leaf: jax.Array, layer: jax.Array, idx: jax.Array,
                  vals: jax.Array) -> jax.Array:
    """Bulk variant of :func:`write_lo_expert`: stage several experts of one
    layer in a single scatter — the cold-start pump issues one device write
    per (layer, leaf) instead of one per expert cell."""
    return leaf.at[layer, idx].set(vals.astype(leaf.dtype))


@jax.jit
def swap_expert_rows(leaf: jax.Array, layer: jax.Array, e: jax.Array,
                     f: jax.Array) -> jax.Array:
    """Swap experts ``e`` and ``f`` at one layer of an (L, E, ...) leaf.

    This is the device half of expert-ownership migration under expert
    parallelism: "expert id" IS the position in every bank/router array, so
    moving an expert to another shard relabels the pair — swap the lo rows
    (this helper), the router columns (``swap_router_cols``), and the host
    mirrors; the forward pass is invariant and needs no changes."""
    a, b = leaf[layer, e], leaf[layer, f]
    return leaf.at[layer, e].set(b).at[layer, f].set(a)


@jax.jit
def swap_router_cols(router: jax.Array, layer: jax.Array, e: jax.Array,
                     f: jax.Array) -> jax.Array:
    """Swap two expert columns of an (L, d_model, E) router at ``layer`` —
    the compensating half of relabeling migration: tokens that routed to
    position ``e`` now route to ``f`` (which holds the same weights)."""
    a, b = router[layer, :, e], router[layer, :, f]
    return router.at[layer, :, e].set(b).at[layer, :, f].set(a)


@jax.jit
def publish(slot_map: jax.Array, slot_owner: jax.Array, layer: jax.Array,
            expert: jax.Array, slot: jax.Array):
    """Atomically publish expert→slot (promotion). slot = −1 demotes: the
    handle falls back to the always-resident lo version first; the hi slot is
    reclaimed afterwards (publish-then-switch, paper §3.2)."""
    old_owner = slot_owner[layer, slot]
    # Demote whoever owned the slot (no-op if free).
    slot_map = slot_map.at[layer, jnp.where(old_owner >= 0, old_owner, 0)].set(
        jnp.where(old_owner >= 0, -1, slot_map[layer, jnp.where(old_owner >= 0, old_owner, 0)]))
    slot_map = slot_map.at[layer, expert].set(slot)
    slot_owner = slot_owner.at[layer, slot].set(
        jnp.where(slot >= 0, expert, slot_owner[layer, slot]))
    return slot_map, slot_owner


@jax.jit
def unpublish(slot_map: jax.Array, slot_owner: jax.Array, layer: jax.Array,
              expert: jax.Array):
    """Demotion: redirect the handle to the lo version and free the slot."""
    slot = slot_map[layer, expert]
    slot_map = slot_map.at[layer, expert].set(-1)
    safe_slot = jnp.where(slot >= 0, slot, 0)
    slot_owner = slot_owner.at[layer, safe_slot].set(
        jnp.where(slot >= 0, -1, slot_owner[layer, safe_slot]))
    return slot_map, slot_owner
