"""Fault-injection harness + fault-tolerant residency (robustness ISSUE).

Layers under test, bottom-up:

* `repro.fault.inject` — seeded counter-based fault plans: determinism,
  cadence/probability rules, first-match-wins.
* `repro.fault.retry` — Philox-jittered exponential backoff that never
  sleeps (modeled time) and retries exactly `TransferFault`.
* `TransitionManager` under injected promotion faults — abort with
  exactly-once refund, stalls held out of publish, corrupt payloads caught
  by the publish-time integrity check, watchdog cancellation.
* `EPCoordinator._migrate` mid-swap abort — bit-exact rollback.
* `HostExpertStore` + streaming shards — transparent retry (token parity)
  and quarantine-then-heal degradation when retries exhaust.
* Engine-level: watchdog requeue of no-progress requests, the structured
  `EngineStallError` snapshot, and a seeded chaos soak (zero request
  failures, invariants at drain).
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ControllerConfig, DynaExqController, build_bank,
                        expert_hi_nbytes)
from repro.core.budget import BudgetTracker
from repro.core.controller import EPCoordinator, RebalanceConfig
from repro.core.ver import Residency
from repro.fault import (FaultPlan, FaultRule, RetryExhausted, RetryPolicy,
                         TransferFault, retry_call)
from repro.serving import (EngineConfig, EngineStallError, InferenceEngine,
                           Request, load_streaming_params, make_backend,
                           make_prompts, save_expert_shards)


def _clone(params):
    return jax.tree_util.tree_map(lambda x: x, params)


def _engine(cfg, params, backend, **ecfg_kw):
    ecfg_kw.setdefault("max_slots", 2)
    ecfg_kw.setdefault("max_len", 48)
    return InferenceEngine(cfg, params, backend, EngineConfig(**ecfg_kw))


def _dynaexq(**kw):
    kw.setdefault("lo_bits", 4)
    kw.setdefault("n_hi_per_layer", 2)
    kw.setdefault("controller", ControllerConfig(update_interval_s=0.0))
    return make_backend("dynaexq", **kw)


def _plan(*rules, seed=7):
    return FaultPlan(seed=seed, rules=tuple(rules))


# -- fault plans & injector -------------------------------------------------

def test_fault_plan_parse_roundtrip(tmp_path):
    text = ('{"seed": 7, "rules": [{"site": "host_lo", "prob": 0.1},'
            ' {"site": "promo_copy", "kind": "stall", "every": 3,'
            ' "stall_s": 0.5}]}')
    plan = FaultPlan.parse(text)
    assert plan.seed == 7 and len(plan.rules) == 2
    assert plan.rules[1].kind == "stall" and plan.rules[1].every == 3
    # seed override + file form + JSON round trip
    f = tmp_path / "plan.json"
    f.write_text(plan.to_json())
    again = FaultPlan.parse(str(f), seed=11)
    assert again.seed == 11 and again.rules == plan.rules
    assert FaultPlan.parse(plan.to_json()) == plan
    with pytest.raises(ValueError):
        FaultRule(site="host_lo", kind="explode")
    with pytest.raises(ValueError):
        FaultRule(site="host_lo", prob=1.5)


def test_injector_deterministic_and_cadence():
    plan = _plan(FaultRule(site="host_lo", prob=0.3),
                 FaultRule(site="promo_copy", every=3, start=1, max_fires=2))
    a, b = plan.injector(), plan.injector()
    seq_a = [a.fire("host_lo") is not None for _ in range(200)]
    seq_b = [b.fire("host_lo") is not None for _ in range(200)]
    assert seq_a == seq_b                      # pure counter function
    assert 20 < sum(seq_a) < 120               # prob actually draws
    fires = [k for k in range(12)
             if a.fire("promo_copy") is not None]
    assert fires == [1, 4]                     # cadence + start + max_fires
    assert a.arrivals("promo_copy") == 12
    assert a.stats["injected"] == sum(seq_a) + 2


def test_injector_first_match_wins():
    plan = _plan(FaultRule(site="host_lo", every=1, max_fires=1),
                 FaultRule(site="host_lo", kind="stall", every=1,
                           stall_s=9.0))
    inj = plan.injector()
    f0 = inj.fire("host_lo")
    f1 = inj.fire("host_lo")
    assert f0.kind == "fail" and f0.rule == 0
    assert f1.kind == "stall" and f1.rule == 1 and f1.stall_s == 9.0


# -- retry policy -----------------------------------------------------------

def test_retry_backoff_deterministic_and_bounded():
    pol = RetryPolicy(max_attempts=5, base_s=0.01, cap_s=0.02)
    d1 = pol.delay_s(1, seed=3, site="host_lo", key=42)
    assert d1 == pol.delay_s(1, seed=3, site="host_lo", key=42)
    assert 0.005 <= d1 < 0.015                 # jitter in [0.5, 1.5) x base
    d4 = pol.delay_s(4, seed=3, site="host_lo", key=42)
    assert d4 < 0.03                           # capped exponential


def test_retry_call_success_exhaustion_and_selectivity():
    calls = [0]

    def flaky():
        calls[0] += 1
        if calls[0] < 3:
            raise TransferFault("host_lo")
        return "ok"

    out, retries, waited = retry_call(flaky, RetryPolicy(max_attempts=4),
                                      site="host_lo")
    assert out == "ok" and retries == 2 and waited > 0.0

    def always():
        raise TransferFault("host_lo")

    with pytest.raises(RetryExhausted) as ei:
        retry_call(always, RetryPolicy(max_attempts=3), site="host_lo")
    assert ei.value.attempts == 3
    assert isinstance(ei.value.__cause__, TransferFault)

    def broken():
        raise ValueError("not a transfer fault")

    with pytest.raises(ValueError):            # non-TransferFault: unretried
        retry_call(broken, RetryPolicy(), site="host_lo")


def test_retry_deadline():
    pol = RetryPolicy(max_attempts=100, base_s=0.05, cap_s=0.05,
                      timeout_s=0.08)
    with pytest.raises(RetryExhausted) as ei:
        retry_call(lambda: (_ for _ in ()).throw(TransferFault("x")), pol,
                   site="x")
    assert ei.value.attempts < 100             # deadline, not attempt cap


# -- transition manager under promotion faults ------------------------------

def _controller(plan=None, n_hi=2, rate_limit=0):
    key = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(key, (1, 8, 64, 32), jax.numpy.float32)
         .astype(jax.numpy.bfloat16)}
    bank = build_bank(w, n_hi=n_hi, lo_bits=4)
    host = {k: np.asarray(v) for k, v in w.items()}
    hib = expert_hi_nbytes({k: v.shape for k, v in w.items()})
    ctl = DynaExqController(
        bank, host, n_hi_per_layer=n_hi, hi_bytes_per_expert=hib,
        cfg=ControllerConfig(update_interval_s=1e9,
                             migration_bytes_per_window=rate_limit))
    if plan is not None:
        ctl.tm.injector = plan.injector()
    return ctl, hib


def test_promo_fail_aborts_and_refunds():
    ctl, hib = _controller(_plan(FaultRule(site="promo_copy", every=1)))
    tm = ctl.tm
    tm.request_promotion(0, 3)
    tm.drain()
    # Every attempt failed: admission aborted, slot + reservation unwound,
    # the expert keeps serving lo, and the controller decayed its score.
    assert tm.hi_set(0) == set() and not tm._pending
    assert tm.state[0, 3] == Residency.RESIDENT_LO.value
    assert tm.tracker.used == 0 and tm.inflight_bytes == 0
    assert tm.stats["fault_cancels"] == 1
    assert ctl._fail_penalty[0, 3] < 1.0
    tm.check_invariants()


def test_promo_retry_then_succeed_transparent():
    # every=2 from arrival 0: attempt fails, its retry succeeds — never two
    # consecutive failures, so the fault is absorbed by the retry loop.
    ctl, hib = _controller(_plan(FaultRule(site="promo_copy", every=2)))
    tm = ctl.tm
    tm.request_promotion(0, 3)
    tm.drain()
    assert tm.publish_ready(wait=True) == 1
    assert tm.hi_set(0) == {3}
    assert tm.stats["retries"] >= 1 and tm.stats["fault_cancels"] == 0
    assert tm.tracker.used == hib
    tm.check_invariants()


def test_promo_stall_holds_publish_and_watchdog_cancels():
    ctl, hib = _controller(_plan(FaultRule(site="promo_copy", kind="stall",
                                           every=1, stall_s=100.0)))
    tm = ctl.tm
    t = [0.0]
    tm.clock = lambda: t[0]
    tm.request_promotion(0, 1)
    tm.drain()
    assert tm.inflight_bytes == hib and len(tm._pending) == 1
    # The copy is "on the wire" until the injected deadline: non-blocking
    # publish must leave it in flight.
    assert tm.publish_ready() == 0 and len(tm._pending) == 1
    tm.check_invariants()
    # Watchdog: past the promo deadline the span cancels with exact refund.
    t[0] = 10.0
    assert tm.cancel_stuck(now=t[0], deadline_s=5.0) == 1
    assert not tm._pending and tm.inflight_bytes == 0
    assert tm.tracker.used == 0
    assert tm.state[0, 1] == Residency.RESIDENT_LO.value
    assert tm.stats["fault_cancels"] == 1
    assert tm.cancel_stuck(now=t[0], deadline_s=5.0) == 0   # idempotent
    tm.check_invariants()


def test_promo_corrupt_never_published():
    ctl, hib = _controller(_plan(FaultRule(site="promo_copy",
                                           kind="corrupt", every=1)))
    tm = ctl.tm
    tm.request_promotion(0, 2)
    tm.drain()
    # The copy lands but fails the publish-time integrity check — the
    # forward must never observe the corrupt version.
    assert tm.publish_ready(wait=True) == 0
    assert tm.hi_set(0) == set()
    assert tm.state[0, 2] == Residency.RESIDENT_LO.value
    assert tm.tracker.used == 0 and tm.inflight_bytes == 0
    assert tm.stats["fault_cancels"] == 1
    tm.check_invariants()


def test_cancel_refund_exactly_once():
    ctl, hib = _controller(_plan(FaultRule(site="promo_copy", kind="stall",
                                           every=1, stall_s=100.0)))
    tm = ctl.tm
    tm.clock = lambda: 0.0
    tm.request_promotion(0, 0)
    tm.drain()
    p = tm._pending[0]
    tm._cancel_pending(p, "timeout")
    used_after_first = tm.tracker.used
    tm._cancel_pending(p, "timeout")           # racing second cancel: no-op
    assert tm.tracker.used == used_after_first == 0
    assert tm.inflight_bytes == 0
    tm._pending = [q for q in tm._pending if not q.cancelled]
    tm.check_invariants()


def test_pending_ages_reported():
    ctl, _ = _controller(_plan(FaultRule(site="promo_copy", kind="stall",
                                         every=1, stall_s=100.0)))
    tm = ctl.tm
    t = [1.0]
    tm.clock = lambda: t[0]
    tm.request_promotion(0, 5)
    tm.drain()
    t[0] = 3.5
    assert tm.pending_ages(t[0]) == [(0, 5, 2.5)]


# -- EP migration rollback --------------------------------------------------

def _ep_controller():
    key = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(key, (1, 8, 64, 32), jax.numpy.float32)
         .astype(jax.numpy.bfloat16)}
    bank = build_bank(w, n_hi=4, lo_bits=4)
    host = {k: np.asarray(v) for k, v in w.items()}
    hib = expert_hi_nbytes({k: v.shape for k, v in w.items()})
    trackers = [BudgetTracker(1 * hib) for _ in range(4)]
    return DynaExqController(
        bank, host, n_hi_per_layer=4, hi_bytes_per_expert=hib,
        cfg=ControllerConfig(update_interval_s=1e9),
        ep_shards=4, shard_trackers=trackers)


@pytest.mark.parametrize("kind", ["fail", "corrupt"])
def test_ep_migration_fault_rolls_back_bit_exact(kind):
    ctl = _ep_controller()
    coord = EPCoordinator(4, RebalanceConfig(interval_s=1e9))
    moe_params = {"router": jax.random.normal(jax.random.PRNGKey(1),
                                              (1, 16, 8),
                                              jax.numpy.float32)}
    coord.register(ctl, moe_params)
    coord.injector = _plan(FaultRule(site="ep_mig", kind=kind,
                                     every=1)).injector()
    r_before = np.asarray(moe_params["router"]).copy()
    lo_before = np.asarray(ctl.bank.lo["w"].packed).copy()
    sc_before = np.asarray(ctl.bank.lo["w"].scales).copy()
    placement_before = coord._entries[0][2].copy()
    assert not coord._migrate(ctl, moe_params, coord._entries[0][2], 0, 1, 7)
    # `fail` aborts before any mutation; `corrupt` aborts mid-swap and must
    # roll the partially relabeled leaves back — either way, bit-exact.
    np.testing.assert_array_equal(np.asarray(moe_params["router"]), r_before)
    np.testing.assert_array_equal(np.asarray(ctl.bank.lo["w"].packed),
                                  lo_before)
    np.testing.assert_array_equal(np.asarray(ctl.bank.lo["w"].scales),
                                  sc_before)
    np.testing.assert_array_equal(coord._entries[0][2], placement_before)
    assert coord.stats["aborted_migrations"] == 1
    assert coord.stats["migrations"] == 0
    ctl.tm.check_invariants()


# -- streaming shards: transparent retry & quarantine -----------------------

@pytest.fixture(scope="module")
def shard_dir(tmp_path_factory, serving_setup):
    cfg, params = serving_setup
    d = tmp_path_factory.mktemp("fault_shards")
    save_expert_shards(str(d), _clone(params), [0], lo_bits=4)
    return str(d)


def test_shard_fault_retry_token_parity(serving_setup, shard_dir):
    """Shard reads that fail once and succeed on retry must be invisible:
    the streamed engine still emits token-for-token what the fault-free
    materialized engine does (staged rows stay bit-identical)."""
    cfg, params = serving_setup
    frozen = ControllerConfig(update_interval_s=1e9)
    prompts = make_prompts("text", cfg.vocab_size, 2, 16)
    eng_a = _engine(cfg, _clone(params), _dynaexq(controller=frozen))
    out_a, _, _ = eng_a.generate({"tokens": prompts}, 6)
    # every=2 from arrival 0: each read's first attempt fails, its retry
    # succeeds — never two consecutive failures, so nothing quarantines.
    plan = _plan(FaultRule(site="shard_lo", every=2))
    be = _dynaexq(controller=frozen, stream=shard_dir,
                  stream_experts_per_tick=3, fault=plan)
    eng_b = _engine(cfg, load_streaming_params(shard_dir), be)
    out_b, _, _ = eng_b.generate({"tokens": prompts}, 6)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))
    st = be.stats()
    assert st["retries"] >= 1
    assert st["quarantined"] == 0 and st["fault_cancels"] == 0
    for store in be.stores.values():
        store.check_invariants()


def test_quarantine_heal_and_degraded_marking(serving_setup, shard_dir):
    """Exhausted shard reads quarantine the affected experts instead of
    blocking `serving_ready()`: the engine opens with them served from
    host (requests marked degraded), the backend re-stages them
    opportunistically, and the quarantine fully heals."""
    cfg, _ = serving_setup
    # Enough fires that the cold-start pump exhausts its retries on every
    # staging batch AND the first few heal attempts fail too; after
    # max_fires the shard "recovers".
    plan = _plan(FaultRule(site="shard_lo", every=1, max_fires=12))
    be = _dynaexq(controller=ControllerConfig(update_interval_s=1e9),
                  stream=shard_dir, stream_experts_per_tick=4, fault=plan)
    eng = _engine(cfg, load_streaming_params(shard_dir), be)
    steps = 0
    while not be.serving_ready():
        eng.step()
        steps += 1
        assert steps < 200
    store = be.stores["0"]
    assert store.stats["quarantines"] >= 1
    assert int(store.quarantined.sum()) > 0     # opened degraded, not wedged
    store.check_invariants()
    # Quarantined cells route as host tier: serving continues, marked
    # degraded, paying the modeled demand-fetch stall.
    prompts = make_prompts("text", cfg.vocab_size, 1, 8)
    h = eng.submit(Request(tokens=prompts[0], max_new_tokens=2))
    while h.state.value != "finished":
        eng.step()
    assert h.degraded
    # Opportunistic healing: once the injected fault budget is spent, the
    # backend re-stages every quarantined cell.
    for _ in range(200):
        if int(store.quarantined.sum()) == 0:
            break
        eng.step()
    assert int(store.quarantined.sum()) == 0
    assert bool(store.lo_valid.all())
    store.check_invariants()
    st = be.stats()
    assert st["quarantined"] == 0 and st["retries"] >= 1


# -- engine: watchdog, stall snapshot, chaos soak ---------------------------

def test_watchdog_requeues_no_progress_request(serving_setup):
    cfg, params = serving_setup
    prompts = make_prompts("text", cfg.vocab_size, 1, 8)
    # Reference run, no watchdog interference.
    eng_a = _engine(cfg, _clone(params), _dynaexq())
    out_a, _, _ = eng_a.generate({"tokens": prompts}, 6)
    eng = _engine(cfg, _clone(params), _dynaexq(),
                  watchdog_no_progress_s=30.0)
    h = eng.submit(Request(tokens=prompts[0], max_new_tokens=6))
    while len(h.tokens) < 2:
        eng.step()
    # Simulate a wedged slot: no token for far longer than the deadline.
    h.last_progress_s -= 1000.0
    eng.step()
    assert eng.counters["watchdog_cancels"] == 1
    assert h.state.value in ("queued", "running")   # requeued, not failed
    eng.drain()
    # Bit-exact snapshot resume: the requeued request finishes with exactly
    # the tokens an undisturbed run produces.
    assert len(h.tokens) == 6
    np.testing.assert_array_equal(np.asarray(h.tokens),
                                  np.asarray(out_a[0]))


def test_engine_stall_error_snapshot(serving_setup):
    cfg, params = serving_setup
    eng = _engine(cfg, _clone(params), _dynaexq(),
                  hbm_budget_bytes=1 << 22)
    # Exhaust the envelope with an out-of-band reservation (external HBM
    # pressure): the submit-time feasibility guard passes (worst-case KV <
    # cap) but no KV block can ever be reserved and nothing in flight can
    # free bytes — the admission loop must trip the structured stall error
    # instead of spinning forever.
    assert eng.budget.try_reserve(eng.budget.cap - eng.budget.used - 1)
    prompts = make_prompts("text", cfg.vocab_size, 1, 8)
    eng.submit(Request(tokens=prompts[0], max_new_tokens=2))
    with pytest.raises(EngineStallError) as ei:
        eng.drain()
    snap = ei.value.snapshot
    assert snap["queued_total"] == 1
    assert sum(snap["queue_depths"].values()) == 1
    assert snap["budget_cap"] == 1 << 22
    assert snap["budget_headroom_frac"] < 0.01
    assert snap["pending_promotions"] == []
    assert 0.0 <= snap["residency_ready_frac"] <= 1.0
    assert "queue depths" in str(ei.value)


def test_chaos_soak_zero_failures_and_invariants(serving_setup):
    """Seeded chaos soak: randomized promotion/host faults under a live
    controller and mixed-QoS traffic. Contract: every request completes
    (degradation never becomes failure), the refund accounting balances,
    hi residents stay a subset of lo residents, and no half-materialized
    bank survives drain."""
    cfg, params = serving_setup

    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def soak(seed):
        _soak_once(cfg, params, seed)

    soak()


def _soak_once(cfg, params, seed):
    plan = _plan(FaultRule(site="promo_copy", prob=0.4),
                 FaultRule(site="promo_copy", kind="corrupt", prob=0.2),
                 FaultRule(site="host_hi", prob=0.3),
                 FaultRule(site="host_lo", prob=0.2),
                 seed=seed)
    be = _dynaexq(fault=plan)
    eng = _engine(cfg, _clone(params), be, max_slots=3,
                  promo_deadline_s=30.0)
    prompts = make_prompts("text", cfg.vocab_size, 3, 12)
    handles = [eng.submit(Request(tokens=prompts[i], max_new_tokens=5,
                                  qos=q))
               for i, q in enumerate(("premium", "standard", "batch"))]
    eng.drain()
    eng.flush()
    for h in handles:
        assert h.state.value == "finished"
        assert len(h.tokens) == 5              # zero request failures
    for ctl in be.controllers.values():
        ctl.tm.check_invariants()              # budget + exactly-once refund
        assert ctl.tm.inflight_bytes == \
            sum(p.nbytes for p in ctl.tm._pending)
    st = eng.stats()
    assert st["retries"] >= 0.0 and st["fault_cancels"] >= 0.0
    # hi ⊆ lo-resident and no dangling slot state — the backend-wide audit.
    for ctl in be.controllers.values():
        for l in range(ctl.tm.state.shape[0]):
            for e in ctl.tm.hi_set(l):
                assert ctl.tm.state[l, e] == Residency.RESIDENT_HI.value
