from repro.serving.backends import (BACKENDS, DynaExqBackend, Fp16Backend,
                                    LRUSet, OffloadBackend, OffloadConfig,
                                    ResidencyBackend, STAT_KEYS,
                                    StaticPTQBackend, make_backend)
from repro.serving.engine import (EngineConfig, InferenceEngine,
                                  RequestHandle, RequestState)
from repro.serving.kvpool import KVBlockPool, KVLease, TRASH_BLOCK
from repro.serving.prefix import PrefixTrie
from repro.serving.requests import (Request, RequestStream, WORKLOADS,
                                    make_prompts, mixed_stream)

__all__ = [
    "BACKENDS", "DynaExqBackend", "EngineConfig", "Fp16Backend",
    "InferenceEngine", "KVBlockPool", "KVLease", "LRUSet", "OffloadBackend",
    "OffloadConfig", "PrefixTrie", "Request", "RequestHandle",
    "RequestState", "RequestStream", "ResidencyBackend", "STAT_KEYS",
    "StaticPTQBackend", "TRASH_BLOCK", "WORKLOADS",
    "make_backend", "make_prompts", "mixed_stream",
]
