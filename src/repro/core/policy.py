"""Budget-feasible top-n selection with hysteresis (paper §3.5).

Given per-layer hotness scores and the fixed per-layer capacity ``n_hi,l``,
the target hi set is TopN — but an expert only *enters* if its score exceeds
the weakest current member by ``margin``, and only *leaves* if it falls below
the strongest outsider by the same margin. This bounds churn under near-tie
routing fluctuations (stability constraint C3) without ever violating the
budget (the set size never exceeds n_hi).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    n_hi: int                  # per-layer hi capacity (budget-derived)
    margin: float = 0.0        # additive hysteresis threshold on scores
    max_transitions_per_layer: int = 0   # 0 = unlimited (rate limiting is
                                         # additionally enforced at admission)


def select_hi_set(scores: np.ndarray, current: set[int],
                  cfg: PolicyConfig) -> tuple[set[int], list[int], list[int]]:
    """One layer. Returns (target_set, promotions, demotions), promotions
    ordered hottest-first and demotions coldest-first (eviction priority)."""
    E = scores.shape[0]
    n = min(cfg.n_hi, E)
    if n == 0:
        return set(), [], sorted(current, key=lambda e: scores[e])
    order = np.argsort(-scores, kind="stable")
    top = order[:n]
    top_set = set(int(e) for e in top)

    if not current:
        target = top_set
    else:
        target = set(current)
        # Hysteresis: rank everyone, then swap in only clear winners.
        in_sorted = sorted(current, key=lambda e: scores[e])          # weakest first
        out_sorted = [int(e) for e in order if int(e) not in current]  # strongest first
        i = j = 0
        while i < len(in_sorted) and j < len(out_sorted):
            weakest_in, strongest_out = in_sorted[i], out_sorted[j]
            if scores[strongest_out] > scores[weakest_in] + cfg.margin:
                target.discard(weakest_in)
                target.add(strongest_out)
                i += 1
                j += 1
            else:
                break
        # Capacity change (re-planned budget) still applies.
        while len(target) > n:
            target.discard(min(target, key=lambda e: scores[e]))
        if len(target) < n:
            for e in order:
                if len(target) >= n:
                    break
                target.add(int(e))

    promotions = sorted(target - current, key=lambda e: -scores[e])
    demotions = sorted(current - target, key=lambda e: scores[e])
    if cfg.max_transitions_per_layer:
        k = cfg.max_transitions_per_layer
        promotions = promotions[:k]
        # Keep the set consistent: only demote as many as we promote over cap.
        overflow = max(0, len(current) + len(promotions) - n)
        demotions = demotions[:max(overflow, min(len(demotions), k))]
        target = (current - set(demotions)) | set(promotions)
    return target, promotions, demotions
