"""Fixed-granularity slot pools (paper §3.3, TPU adaptation).

On CUDA the paper fights allocator fragmentation with fixed-size block pools
and constant-time free lists. In JAX the device arrays are preallocated once,
so fragmentation cannot occur; what remains is the *slot accounting*: which
hi-pool slot is free, which expert owns which slot. ``SlotPool`` is that
free list, host-side, one per layer.

Allocation is lowest-index-first (a min-heap, O(log n)): occupied hi slots
pack toward the low end of the pool, so after churn the live slots stay a
(mostly) contiguous prefix of the (n_hi, K, N) pool arrays. That layout is
what the ragged decode kernel's BlockSpec indexing wants — the hi-slot
blocks a step touches cluster instead of striding across the whole pool —
and it costs nothing over the previous LIFO list.
"""
from __future__ import annotations

import heapq


class SlotPool:
    """Lowest-index-first free list over ``n_slots`` fixed-granularity
    slots (constant-time membership, log-time alloc/free)."""

    def __init__(self, n_slots: int):
        self._free = list(range(n_slots))     # already a valid min-heap
        self._owner: dict[int, int] = {}      # slot → expert
        self.n_slots = n_slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self, expert: int) -> int:
        """Pop the lowest free slot for ``expert``; raises if full (the
        admission check must prevent that)."""
        if not self._free:
            raise RuntimeError("pool exhausted — admission control bug")
        slot = heapq.heappop(self._free)
        self._owner[slot] = expert
        return slot

    def free(self, slot: int) -> None:
        if slot in self._owner:
            del self._owner[slot]
            heapq.heappush(self._free, slot)

    def owner(self, slot: int) -> int | None:
        return self._owner.get(slot)

    def slots_of(self) -> dict[int, int]:
        return dict(self._owner)


class ShardedSlotPool:
    """Per-shard free lists over one global slot index space.

    Under expert parallelism the hi pool is sharded along the slot dim:
    shard ``j`` physically holds slots ``[j·per, (j+1)·per)`` in its own
    HBM, and an expert owned by shard ``j`` may only occupy one of those
    slots (the kernel reads hi weights from local memory). ``alloc`` is
    therefore per-shard; everything else (free/owner/slots_of) stays in
    the global slot space so the bank's ``slot_map``/``slot_owner``
    handles are unchanged. ``n_shards=1`` degenerates to ``SlotPool``.
    """

    def __init__(self, n_slots: int, n_shards: int = 1):
        if n_shards < 1 or n_slots % n_shards:
            raise ValueError(
                f"n_slots={n_slots} must divide evenly over n_shards={n_shards}")
        self.n_slots = n_slots
        self.n_shards = n_shards
        self.per_shard = n_slots // n_shards
        self._free = [list(range(j * self.per_shard, (j + 1) * self.per_shard))
                      for j in range(n_shards)]
        self._owner: dict[int, int] = {}

    def shard_of(self, slot: int) -> int:
        return slot // self.per_shard

    @property
    def n_free(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def n_used(self) -> int:
        return self.n_slots - self.n_free

    def n_free_in(self, shard: int) -> int:
        return len(self._free[shard])

    def alloc(self, expert: int, shard: int = 0) -> int:
        """Pop the lowest free slot of ``shard`` for ``expert``; raises if
        that shard's slots are exhausted (admission must prevent it)."""
        if not self._free[shard]:
            raise RuntimeError(
                f"shard {shard} pool exhausted — admission control bug")
        slot = heapq.heappop(self._free[shard])
        self._owner[slot] = expert
        return slot

    def free(self, slot: int) -> None:
        if slot in self._owner:
            del self._owner[slot]
            heapq.heappush(self._free[self.shard_of(slot)], slot)

    def owner(self, slot: int) -> int | None:
        return self._owner.get(slot)

    def slots_of(self) -> dict[int, int]:
        return dict(self._owner)
