"""Fault tolerance under injected transfer faults (robustness ISSUE).

The degradation claim, measured: with 10% of demand host fetches failing
(each failure costs one extra modeled transfer + backoff before the retry
lands), the serving engine must keep **100% request success** and at least
**75% of the fault-free effective throughput**. Faults degrade latency,
never availability — the whole point of retry + refund + quarantine over
crash-on-first-error.

Both runs serve the same mixed workload on the shared trained bench model
with a host tier forced into play (``lo_resident_total`` below the cell
count, so cold experts live in host DRAM and demand fetches actually
happen). Effective throughput divides tokens by wall time **plus modeled
stall** — the injected faults are modeled (deterministic, virtual-clock
compatible), so the stall clock is where their cost shows up.

Rows land in ``experiments/BENCH_faults.json``; thresholds are asserted,
not just reported. ``BENCH_SMOKE=1`` shrinks the sweep.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import BENCH_SMOKE, clone, trained_model
from repro.core import ControllerConfig
from repro.fault import FaultPlan, FaultRule
from repro.serving import (EngineConfig, FetchModel, InferenceEngine,
                           Request, make_backend, make_prompts)

N_REQ = 4 if BENCH_SMOKE else 8
N_NEW = 4 if BENCH_SMOKE else 8
PROMPT = 32
FAIL_PROB = 0.10
MIN_TPUT_RATIO = 0.75
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_faults.json")


def _serve(cfg, params, plan):
    be = make_backend(
        "dynaexq", lo_bits=4, n_hi_per_layer=2,
        lo_resident_total=20,            # force a host tier: demand fetches
        fetch=FetchModel(gbps=8.0),
        controller=ControllerConfig(update_interval_s=0.0),
        fault=plan)
    eng = InferenceEngine(cfg, clone(params), be,
                          EngineConfig(max_slots=4, max_len=96))
    handles = []
    for w in ("text", "math"):
        toks = make_prompts(w, cfg.vocab_size, N_REQ // 2, PROMPT)
        handles += [eng.submit(Request(tokens=toks[b], max_new_tokens=N_NEW))
                    for b in range(N_REQ // 2)]
    t0 = time.perf_counter()
    eng.drain()
    wall_s = time.perf_counter() - t0
    eng.flush()
    st = eng.stats()
    ok = sum(1 for h in handles
             if h.state.value == "finished" and len(h.tokens) == N_NEW)
    tokens = sum(len(h.tokens) for h in handles)
    stall_s = eng._stall_clock
    return {"tokens": tokens,
            "success_rate": ok / len(handles),
            "wall_s": wall_s,
            "modeled_stall_s": float(stall_s),
            "eff_tput_tok_s": tokens / (wall_s + stall_s),
            "host_fetches": float(st["host_fetches"]),
            "retries": float(st["retries"]),
            "fault_cancels": float(st["fault_cancels"])}


def run(report) -> None:
    cfg, params, _ = trained_model()
    _serve(cfg, params, None)                  # warm every jit cache
    base = _serve(cfg, params, None)
    # every=10 ≡ a deterministic 10% failure rate (a prob draw over the few
    # dozen fetch windows of a smoke run can legitimately produce zero
    # fires — the cadence form keeps the measured point at exactly 10%).
    plan = FaultPlan(seed=7, rules=(
        FaultRule(site="host_fetch", every=int(round(1 / FAIL_PROB))),))
    faulted = _serve(cfg, params, plan)
    assert faulted["retries"] >= 1, "no fault ever fired — dead harness"
    ratio = faulted["eff_tput_tok_s"] / base["eff_tput_tok_s"]
    assert faulted["success_rate"] == 1.0, (
        f"injected host-fetch faults must never fail a request "
        f"(success {faulted['success_rate']:.2f})")
    assert base["success_rate"] == 1.0
    assert ratio >= MIN_TPUT_RATIO, (
        f"effective throughput under {FAIL_PROB:.0%} host-fetch failure is "
        f"{ratio:.2f}x fault-free — below the {MIN_TPUT_RATIO:.2f}x floor")
    out = {"fault_free": base, "faulted": faulted,
           "fail_prob": FAIL_PROB, "tput_ratio": ratio,
           "min_tput_ratio": MIN_TPUT_RATIO}
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(out, f, indent=2)
    report("fault_tolerance/tput_ratio", ratio * 1e6,
           f"ratio={ratio:.3f} retries={faulted['retries']:.0f} "
           f"success={faulted['success_rate']:.0%}")
    report("fault_tolerance/json", 0.0, JSON_OUT)


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
