"""Trace replayer: measured expert-weight traffic vs the roofline model.

``launch/roofline.py`` carries an analytic bytes/token model
(``predict_moe_bytes_per_token``) that until now nothing validated. This
module closes the loop: it folds a flight-recorder trace's per-forward
``moe_forward`` events into **measured** bytes/token — the actual
routed-expert tier mix each step streamed — and compares against the
analytic prediction per (batch, residency-mix) bucket, reporting relative
residuals.

Measured traffic per forward (matching ``benchmarks.kernels_bench``'s
byte decomposition):

* ``ragged``  — only active cells stream, at their resident tier:
  ``active_hi·hi_b + active_lo·lo_b``;
* ``padded``  — every layer streams its full lo tier plus every published
  hi slot: ``layers·E·lo_b + published_hi·hi_b``.

The prediction uses the same prices but *expected* activity (uniform-router
coupon collector), so the residual is routing skew + temporal correlation —
the quantity that decides ragged-vs-padded dispatch at a given batch.

Inputs are either a live ``FlightRecorder`` or a saved Chrome trace JSON;
byte prices and dispatch mode ride in the trace metadata
(``FlightRecorder.meta`` → ``otherData``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.launch.roofline import predict_moe_bytes_per_token
from repro.obs.trace import FlightRecorder, load_chrome_trace

#: Metadata keys the replayer needs (written by the engine at attach time).
META_KEYS = ("moe_dispatch", "num_experts", "top_k", "lo_bytes", "hi_bytes")


def _extract(trace) -> Tuple[Dict, List[Dict]]:
    """Normalize a FlightRecorder / chrome-JSON dict / path into
    ``(meta, moe_forward arg dicts in order)``."""
    if isinstance(trace, FlightRecorder):
        meta = dict(trace.meta)
        evs = [dict(e.args or {}) for e in trace.instants("moe_forward")]
        return meta, evs
    obj = load_chrome_trace(trace) if isinstance(trace, str) else trace
    meta = dict(obj.get("otherData", {}))
    evs = [dict(e.get("args", {})) for e in obj.get("traceEvents", [])
           if e.get("name") == "moe_forward" and e.get("ph") == "i"]
    return meta, evs


def measured_bytes_per_token(ev: Dict, meta: Dict) -> Tuple[float, float]:
    """One forward's (tokens, measured expert bytes) under the configured
    dispatch. ``tokens`` derives from the routed assignment count (robust
    to speculative verify folding several steps into one event)."""
    lo_b, hi_b = meta["lo_bytes"], meta["hi_bytes"]
    layers = max(1, int(ev.get("layers", 1)))
    top_k = max(1, int(meta.get("top_k", 1)))
    tokens = ev.get("routed", 0) / (layers * top_k)
    if meta.get("moe_dispatch") == "padded":
        nbytes = (layers * meta["num_experts"] * lo_b +
                  ev.get("published_hi", 0) * hi_b)
    else:
        nbytes = ev.get("active_lo", 0) * lo_b + ev.get("active_hi", 0) * hi_b
    return tokens, float(nbytes)


def fold_steps(trace, decode_only: bool = True) -> List[Dict]:
    """Per-forward samples: tokens, measured/predicted bytes-per-token and
    the residency mix, one dict per ``moe_forward`` event (prefills skipped
    by default — the roofline question is decode traffic)."""
    meta, evs = _extract(trace)
    missing = [k for k in META_KEYS if k not in meta]
    if missing:
        raise ValueError(f"trace metadata missing {missing}; was the "
                         f"recorder attached to an engine?")
    out: List[Dict] = []
    for ev in evs:
        if decode_only and ev.get("prefill"):
            continue
        tokens, nbytes = measured_bytes_per_token(ev, meta)
        if tokens <= 0:
            continue
        layers = max(1, int(ev.get("layers", 1)))
        pred = predict_moe_bytes_per_token(
            tokens, layers, meta["num_experts"], meta["top_k"],
            meta["lo_bytes"], meta["hi_bytes"],
            published_hi=int(ev.get("published_hi", 0)),
            dispatch=meta["moe_dispatch"])
        out.append({
            "tokens": tokens,
            "layers": layers,
            "published_hi": int(ev.get("published_hi", 0)),
            "active_hi": int(ev.get("active_hi", 0)),
            "active_lo": int(ev.get("active_lo", 0)),
            "active_host": int(ev.get("active_host", 0)),
            "measured_bpt": nbytes / tokens,
            "predicted_bpt": pred,
        })
    return out


def _mix_bucket(s: Dict) -> float:
    """Residency-mix key: published-hi fraction of the model, rounded to
    1/16ths so windows with near-identical mixes pool together."""
    # layers in the sample counts layer-steps; cells = layers × E is not
    # carried per sample, so bucket on hi-per-layer instead (integer-ish).
    return round(s["published_hi"] / s["layers"], 2)


def residual_report(trace, decode_only: bool = True) -> Dict:
    """The measured-vs-roofline comparison: per (batch-tokens,
    residency-mix) bucket mean measured and predicted bytes/token plus the
    relative residual ``measured/predicted − 1``, and an overall
    |residual| summary. Empty traces yield ``n_steps == 0``."""
    samples = fold_steps(trace, decode_only=decode_only)
    buckets: Dict[Tuple[float, float], List[Dict]] = {}
    for s in samples:
        buckets.setdefault((round(s["tokens"], 1), _mix_bucket(s)),
                           []).append(s)
    rows = []
    for (tokens, mix), group in sorted(buckets.items()):
        meas = float(np.mean([g["measured_bpt"] for g in group]))
        pred = float(np.mean([g["predicted_bpt"] for g in group]))
        rows.append({
            "tokens": tokens,
            "hi_per_layer": mix,
            "n_steps": len(group),
            "measured_bpt": round(meas, 2),
            "predicted_bpt": round(pred, 2),
            "rel_residual": round(meas / pred - 1.0, 4) if pred else 0.0,
        })
    res = [abs(r["rel_residual"]) for r in rows for _ in range(r["n_steps"])]
    return {
        "n_steps": len(samples),
        "buckets": rows,
        "mean_abs_rel_residual": round(float(np.mean(res)), 4) if res
        else 0.0,
        "max_abs_rel_residual": round(float(np.max(res)), 4) if res else 0.0,
    }


def promotion_report(trace) -> Dict:
    """Promotion publish-latency percentiles from the lifecycle spans
    (copy issue → publish) plus the half-materialization audit: every
    publish event must carry ``published`` ∈ {0, 1} — a span that ended
    published implies its copy's result arrays were ready, i.e. no forward
    observed a half-materialized expert."""
    if isinstance(trace, FlightRecorder):
        spans = [(b.ts, e.ts, (e.args or {}))
                 for b, e in trace.spans("promotion")]
    else:
        obj = load_chrome_trace(trace) if isinstance(trace, str) else trace
        begins: Dict[int, float] = {}
        spans = []
        for ev in obj.get("traceEvents", []):
            if ev.get("name") != "promotion":
                continue
            if ev.get("ph") == "b":
                begins[ev["id"]] = ev["ts"] / 1e6
            elif ev.get("ph") == "e" and ev.get("id") in begins:
                spans.append((begins.pop(ev["id"]), ev["ts"] / 1e6,
                              ev.get("args", {})))
    lat = [e - b for b, e, a in spans if a.get("published")]
    cancelled = sum(1 for _, _, a in spans if not a.get("published"))
    arr = np.asarray(lat) if lat else np.zeros(0)
    return {
        "n_published": len(lat),
        "n_cancelled": cancelled,
        "publish_latency_p50_s": float(np.percentile(arr, 50)) if lat
        else 0.0,
        "publish_latency_p95_s": float(np.percentile(arr, 95)) if lat
        else 0.0,
        "publish_latency_max_s": float(arr.max()) if lat else 0.0,
    }


def report(trace) -> Dict:
    """Everything the shutdown summary / benchmark wants in one dict."""
    return {"roofline": residual_report(trace),
            "promotions": promotion_report(trace)}
