"""Phi-4-mini-3.8B — dense decoder, RoPE + SwiGLU + GQA. [arXiv:2412.08905]"""
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    vocab_size=200064,
    d_ff=8192,
    attn=AttnConfig(n_heads=24, n_kv_heads=8, head_dim=128,
                    rope_theta=10000.0),
    norm_eps=1e-5,
    max_seq_len=131072,
    source="arXiv:2412.08905 (Phi-4)",
)
