"""Length-bucketed masked prefill + per-row routing telemetry.

Covers the ISSUE-2 acceptance criteria end to end:

* padded-bucket prefill is BIT-IDENTICAL to exact-length single-row prefill
  (logits and KV/SSM cache rows), for attention, sliding-window ring caches
  and mamba (SSD) stacks;
* per-row router counts exclude prompt padding and vacant decode slots, so
  a hotness EMA fed from a fully-occupied engine matches one fed from the
  same traffic interleaved with vacant slots bit-for-bit;
* a mixed-length request stream (≥8 distinct prompt lengths) compiles at
  most ``#buckets`` prefill executables.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.hotness import HotnessEstimator
from repro.models import decode_step, init_caches, init_params, prefill
from repro.models.config import AttnConfig
from repro.serving import (EngineConfig, Fp16Backend, InferenceEngine,
                           Request, make_backend, make_prompts)
from repro.serving.engine import _prefill_jit


def _pad_to(toks_rows, bucket, pad=0):
    out = np.full((len(toks_rows), bucket), pad, np.int32)
    for r, t in enumerate(toks_rows):
        out[r, : len(t)] = t
    return out


# ---------------------------------------------------------------------------
# Model-level parity
# ---------------------------------------------------------------------------

def test_padded_prefill_matches_exact(serving_setup):
    """Padded-bucket prefill == exact-length single-row prefill, bit for
    bit: per-row logits, per-row KV cache prefixes, per-row counts."""
    cfg, params = serving_setup
    lens = [5, 11, 17]
    rows = [make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0]
            for ln in lens]
    padded = _pad_to(rows, 32)
    lengths = jnp.asarray(np.array(lens, np.int32))
    lg_pad, caches_pad, counts = prefill(
        params, cfg, {"tokens": jnp.asarray(padded)},
        init_caches(cfg, len(lens), 64), capacity_factor=8.0,
        lengths=lengths, per_row_counts=True)

    for r, (ln, row) in enumerate(zip(lens, rows)):
        lg, c1, _ = prefill(params, cfg, {"tokens": jnp.asarray(row[None])},
                            init_caches(cfg, 1, 64), capacity_factor=8.0)
        np.testing.assert_array_equal(np.asarray(lg[0]),
                                      np.asarray(lg_pad[r]))
        for p, cc in c1.blocks.items():
            np.testing.assert_array_equal(
                np.asarray(cc.k)[:, 0, :, :ln],
                np.asarray(caches_pad.blocks[p].k)[:, r, :, :ln])
            np.testing.assert_array_equal(
                np.asarray(cc.v)[:, 0, :, :ln],
                np.asarray(caches_pad.blocks[p].v)[:, r, :, :ln])

    # per-row counts: exactly top_k selections per REAL token, none for pad
    rc = np.asarray(counts["0"])                       # (nsb, B, E)
    np.testing.assert_array_equal(
        rc.sum(axis=(0, 2)),
        cfg.moe.top_k * np.array(lens) * cfg.n_superblocks())


def test_padded_prefill_zero_length_row_inert(serving_setup):
    """A lengths==0 batch-pad row contributes no counts and the other rows
    are unaffected by its presence."""
    cfg, params = serving_setup
    row = make_prompts("code", cfg.vocab_size, 1, 9, seed=1)[0]
    padded = _pad_to([row, row], 32)
    lg, _, counts = prefill(params, cfg, {"tokens": jnp.asarray(padded)},
                            init_caches(cfg, 2, 64), capacity_factor=8.0,
                            lengths=jnp.asarray([9, 0]), per_row_counts=True)
    rc = np.asarray(counts["0"])
    assert rc[:, 1].sum() == 0
    lg1, _, _ = prefill(params, cfg, {"tokens": jnp.asarray(row[None])},
                        init_caches(cfg, 1, 64), capacity_factor=8.0)
    np.testing.assert_array_equal(np.asarray(lg1[0]), np.asarray(lg[0]))


def test_padded_prefill_ssm_state_parity():
    """Masked SSD prefill: padded rows leave the recurrent state and conv
    window exactly as the last REAL token left them (dt=0 pass-through +
    per-row conv-tail gather), so decode continues bit-identically."""
    cfg = get_config("mamba2-130m", reduced=True)
    params = init_params(jax.random.PRNGKey(1), cfg)
    Q = cfg.ssm.chunk
    L, S = 2 * Q, 4 * Q
    toks = make_prompts("math", cfg.vocab_size, 1, S, seed=2)
    lg_pad, caches_pad, _ = prefill(
        params, cfg, {"tokens": jnp.asarray(toks)}, init_caches(cfg, 1, S),
        capacity_factor=8.0, lengths=jnp.asarray([L]))
    lg_ref, caches_ref, _ = prefill(
        params, cfg, {"tokens": jnp.asarray(toks[:, :L])},
        init_caches(cfg, 1, S), capacity_factor=8.0)
    np.testing.assert_array_equal(np.asarray(lg_ref), np.asarray(lg_pad))
    np.testing.assert_array_equal(
        np.asarray(caches_ref.blocks["0"].state),
        np.asarray(caches_pad.blocks["0"].state))
    np.testing.assert_array_equal(
        np.asarray(caches_ref.blocks["0"].conv),
        np.asarray(caches_pad.blocks["0"].conv))
    tok = jnp.asarray(np.array([7], np.int32))
    lg_d_pad, _, _ = decode_step(params, cfg, tok, jnp.int32(L), caches_pad,
                                 capacity_factor=8.0)
    lg_d_ref, _, _ = decode_step(params, cfg, tok, jnp.int32(L), caches_ref,
                                 capacity_factor=8.0)
    np.testing.assert_array_equal(np.asarray(lg_d_ref), np.asarray(lg_d_pad))


def test_sliding_window_ring_masked_write():
    """Short row in a long bucket with a ring (sliding-window) cache: the
    per-row masked write keeps each row's true window, not the batch tail
    (which would be pure padding for the short row)."""
    from repro.models import layers as L

    acfg = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=8, sliding_window=8)
    p = L.init_attention(jax.random.PRNGKey(0), 16, acfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16),
                          jnp.bfloat16)
    ln = 13
    # exact: prefill only the real tokens
    _, cache_ref = L.attention_prefill(p, acfg, x[:, :ln],
                                       L.init_kv_cache(1, 8, acfg))
    # padded: full 32-wide bucket with a per-row length
    _, cache_pad = L.attention_prefill(p, acfg, x,
                                       L.init_kv_cache(1, 8, acfg),
                                       lengths=jnp.asarray([ln]))
    np.testing.assert_array_equal(np.asarray(cache_ref.k),
                                  np.asarray(cache_pad.k))
    np.testing.assert_array_equal(np.asarray(cache_ref.v),
                                  np.asarray(cache_pad.v))
    # and decode from both caches agrees bit-for-bit
    xd = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 16), jnp.bfloat16)
    out_ref, _ = L.attention_decode(p, acfg, xd, jnp.int32(ln), cache_ref)
    out_pad, _ = L.attention_decode(p, acfg, xd, jnp.int32(ln), cache_pad)
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pad))


def test_decode_row_valid_masks_counts(serving_setup):
    """decode_step(row_valid=...) zeroes vacant rows' router counts without
    touching valid rows' logits."""
    cfg, params = serving_setup
    caches = init_caches(cfg, 3, 64)
    toks = make_prompts("text", cfg.vocab_size, 3, 8)
    _, caches, _ = prefill(params, cfg, {"tokens": jnp.asarray(toks)},
                           caches, capacity_factor=8.0)
    tok = jnp.asarray(np.array([1, 2, 3], np.int32))
    valid = jnp.asarray([True, False, True])
    lg_m, _, counts = decode_step(params, cfg, tok, jnp.int32(8), caches,
                                  capacity_factor=8.0, row_valid=valid,
                                  per_row_counts=True)
    lg, _, _ = decode_step(params, cfg, tok, jnp.int32(8), caches,
                           capacity_factor=8.0)
    rc = np.asarray(counts["0"])                       # (nsb, 3, E)
    assert rc[:, 1].sum() == 0
    assert rc[:, 0].sum() == cfg.moe.top_k * cfg.n_superblocks()
    np.testing.assert_array_equal(np.asarray(lg[0]), np.asarray(lg_m[0]))
    np.testing.assert_array_equal(np.asarray(lg[2]), np.asarray(lg_m[2]))


# ---------------------------------------------------------------------------
# Engine-level: hotness parity + compile count
# ---------------------------------------------------------------------------

class _RecordingBackend(Fp16Backend):
    """Fp16 backend that snapshots every CLEANED per-forward count dict
    (after observe()'s row masking) for step-by-step EMA replay."""

    def __init__(self):
        super().__init__()
        self.trace = []

    def _observe_residency(self, counts, compute_s):
        self.trace.append({k: v.copy() for k, v in counts.items()})
        return 0.0


def _bucketed_engine(cfg, params, backend, max_slots, max_len=64,
                     prefill_rows=2, paged=True):
    clone = jax.tree_util.tree_map(lambda x: x, params)
    return InferenceEngine(cfg, clone, backend,
                           EngineConfig(max_slots=max_slots, max_len=max_len,
                                        prefill_rows=prefill_rows,
                                        paged=paged))


def test_vacant_slot_masking_hotness_identical(serving_setup):
    """The acceptance bit: a fully-occupied engine and an engine with twice
    the slots (so half stay vacant through every decode) produce BIT-
    IDENTICAL hotness EMAs from the same traffic."""
    cfg, params = serving_setup
    reqs = [Request(tokens=make_prompts("text", cfg.vocab_size, 1, ln,
                                        seed=ln)[0], max_new_tokens=5)
            for ln in (7, 12)]

    scores = []
    for max_slots in (2, 4):                  # 4 ⇒ two vacant decode rows
        backend = _RecordingBackend()
        eng = _bucketed_engine(cfg, params, backend, max_slots)
        for r in reqs:
            eng.submit(Request(tokens=r.tokens,
                               max_new_tokens=r.max_new_tokens))
        eng.drain()
        nsb = cfg.n_superblocks()
        est = HotnessEstimator(nsb, cfg.moe.num_experts, alpha=0.8)
        for step_counts in backend.trace:
            est.observe(step_counts["0"])
            est.fold()
        scores.append(est.scores.copy())
    assert len(scores[0]) and (scores[0] == scores[1]).all()
    # and the raw accumulated router counts agree exactly too
    np.testing.assert_array_equal(scores[0], scores[1])


@pytest.mark.parametrize("paged", [False, True])
def test_mixed_length_stream_compiles_per_bucket(serving_setup, paged):
    """≥8 distinct prompt lengths admit through at most #buckets prefill
    executables (the O(#buckets) compile bound) — guarded on the jit cache
    of whichever prefill entry point the engine mode actually uses (the
    dense parity path still ships and must not regress either)."""
    from repro.serving.engine import _prefill_paged_jit
    cfg, params = serving_setup
    eng = _bucketed_engine(cfg, params, make_backend("fp16"), max_slots=4,
                           max_len=64, prefill_rows=4, paged=paged)
    jit_fn = _prefill_paged_jit if paged else _prefill_jit
    lens = (4, 7, 9, 13, 18, 23, 29, 33, 41, 55)
    assert len(set(lens)) >= 8
    before = jit_fn._cache_size()
    handles = [eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0],
        max_new_tokens=2)) for ln in lens]
    eng.drain()
    n_buckets = len(eng.buckets)
    assert len(eng.prefill_shapes) <= n_buckets, eng.prefill_shapes
    assert jit_fn._cache_size() - before <= (2 if paged else 1) * n_buckets
    assert all(len(h.tokens) == 2 for h in handles)
    assert eng.counters["prefills"] < len(lens)   # batched admission


def test_bucket_ladder_geometry(serving_setup):
    cfg, params = serving_setup
    eng = _bucketed_engine(cfg, params, make_backend("fp16"), max_slots=2,
                           max_len=96)
    assert eng.buckets == (32, 64, 96)
    assert eng._bucket_len(1) == 32
    assert eng._bucket_len(33) == 64
    assert eng._bucket_len(96) == 96
    with pytest.raises(ValueError):
        eng.submit(Request(tokens=np.zeros(97, np.int32)))


def test_bucket_ladder_ssm_chunk_multiple():
    """Stacks with mamba layers get chunk-multiple buckets (SSD requires
    S % chunk == 0) and serve mixed-length prompts through them."""
    cfg = get_config("mamba2-130m", reduced=True)
    cfg = dataclasses.replace(cfg, n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, make_backend("fp16"),
                          EngineConfig(max_slots=2, max_len=64,
                                       prefill_rows=2))
    Q = cfg.ssm.chunk
    assert all(b % Q == 0 for b in eng.buckets), (eng.buckets, Q)
    handles = [eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0],
        max_new_tokens=2)) for ln in (5, 19, 40)]   # none chunk-aligned
    eng.drain()
    assert all(len(h.tokens) == 2 for h in handles)


def test_engine_expert_counts_telemetry(serving_setup):
    """Per-request routing telemetry: each handle's expert_counts sums to
    top_k × (prompt + decoded tokens) × nsb selections, and the sum over
    handles equals the backend's accumulated (already cleaned) counts."""
    cfg, params = serving_setup
    eng = _bucketed_engine(cfg, params, make_backend("fp16"), max_slots=3)
    lens, new = (6, 15, 9), 3
    handles = [eng.submit(Request(
        tokens=make_prompts("math", cfg.vocab_size, 1, ln, seed=ln)[0],
        max_new_tokens=new)) for ln in lens]
    eng.drain()
    nsb, k = cfg.n_superblocks(), cfg.moe.top_k
    total = np.zeros_like(handles[0].expert_counts["0"])
    for h, ln in zip(handles, lens):
        # prompt tokens + all decode steps except the last generated token
        # (its forward never runs — the request finishes on emission)
        assert h.expert_counts["0"].sum() == k * nsb * (ln + new - 1)
        total = total + h.expert_counts["0"]
    np.testing.assert_array_equal(total, eng.backend.router_counts()["0"])


def test_hotness_row_resolved_observe():
    est = HotnessEstimator(2, 4)
    rc = np.zeros((2, 3, 4), np.int64)
    rc[:, 0, 1] = 5
    rc[:, 1, 2] = 7       # vacant row — must be dropped
    rc[:, 2, 3] = 1
    est.observe(rc, row_valid=np.array([True, False, True]))
    expect = np.zeros((2, 4), np.int64)
    expect[:, 1], expect[:, 3] = 5, 1
    np.testing.assert_array_equal(est.counts, expect)
    with pytest.raises(ValueError):
        est.observe(np.zeros((3, 4)))
