"""Training driver: train a ~100M-parameter MoE for a few hundred steps on
the synthetic LM pipeline, with checkpointing and held-out perplexity.

    PYTHONPATH=src python examples/train_moe.py --steps 300 [--small]

``--small`` shrinks to smoke size for a fast run; the default is a ~100M
Qwen3-MoE-family model (8 layers, d_model 512, 16 experts top-4).
"""
import argparse
import dataclasses
import os

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.models.config import AttnConfig, MoEConfig
from repro.training import (SyntheticLMTask, TrainConfig, load_checkpoint,
                            save_checkpoint, train_loop)
from repro.training.adamw import AdamWConfig
from repro.training.train import eval_perplexity


def config_100m():
    base = get_config("qwen3-moe-30b-a3b")
    return dataclasses.replace(
        base, name="qwen3-moe-100m", n_layers=8, d_model=512,
        vocab_size=8192, max_seq_len=2048,
        attn=AttnConfig(n_heads=8, n_kv_heads=2, head_dim=64,
                        rope_theta=1e6, qk_norm=True),
        moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=512,
                      norm_topk_prob=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="experiments/train_moe_ckpt")
    args = ap.parse_args()

    cfg = get_config("qwen3-moe-30b-a3b", reduced=True) if args.small \
        else config_100m()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params "
          f"({cfg.moe.num_experts} experts top-{cfg.moe.top_k})")

    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=2e-3, warmup_steps=20, total_steps=args.steps))
    B, S = (16, 65) if args.small else (8, 129)
    params, opt, hist = train_loop(cfg, params,
                                   task.batches(B, S, args.steps), tcfg,
                                   log_every=25)
    save_checkpoint(args.ckpt, params, step=args.steps)
    ppl = eval_perplexity(cfg, params,
                          task.batches(B, S, 4, seed=10_000))
    print(f"held-out perplexity after {args.steps} steps: {ppl:.2f} "
          f"(uniform would be {cfg.vocab_size})")
    print(f"checkpoint: {os.path.abspath(args.ckpt)}")


if __name__ == "__main__":
    main()
