"""Paper Figs 6–9: TTFT, TPOT, end-to-end latency, throughput vs batch size
for fp16 / static PTQ / DynaExq / ExpertFlow-style offloading — all four as
``ResidencyBackend``s behind literally the same ``InferenceEngine`` loop, so
the comparison is structural, not an artifact of per-baseline serving code.

Compute is measured on CPU; the host↔device transfer costs (the quantity the
paper's comparison is actually about) use the deterministic PCIe model
inside the backends, so the ordering reflects transfer volume on/off the
critical path. DynaExq's background promotions are charged to the migration
stream (off critical path) and reported as ``bytes_moved``; offloading's
demand misses stall the step (``stall_s``, on critical path) — the paper's
structural distinction, now visible in one uniform stats table.

Two extras beyond the paper figures:

* a **mixed-length workload** (≥8 distinct prompt lengths) demonstrating
  length-bucketed admission: the engine compiles one prefill executable per
  bucket instead of one per distinct length, and admission batches several
  prompts per forward (``prefills`` ≪ ``admitted``);
* every row lands in ``experiments/BENCH_serving.json`` (uniform ``stats()``
  schema per backend) so the perf trajectory is machine-comparable across
  PRs.

``BENCH_SMOKE=1`` shrinks the sweep for CI smoke runs.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (BENCH_SMOKE, bench_backend, clone,
                               trained_model)
from repro.core import ControllerConfig
from repro.serving import (EngineConfig, InferenceEngine, Request, STAT_KEYS)

N_NEW = 4 if BENCH_SMOKE else 8
PROMPT = 48
KINDS = ("fp16", "static", "dynaexq", "offload")
BATCH_SIZES = (2,) if BENCH_SMOKE else (1, 4, 8)
MIXED_LENS = (4, 7, 11, 16, 23, 30, 41, 52) if BENCH_SMOKE else \
    (4, 7, 11, 16, 23, 30, 41, 52, 61, 77, 85, 90)
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_serving.json")


def _backend(kind):
    return bench_backend(kind, controller=ControllerConfig(
        update_interval_s=0.05, migration_bytes_per_window=1 << 20))


def _run_engine(kind, cfg, params, bs, toks):
    import time
    eng = InferenceEngine(cfg, clone(params), _backend(kind),
                          EngineConfig(max_slots=bs, max_len=96))
    t0 = time.perf_counter()
    for i in range(bs):
        eng.submit(Request(tokens=toks[i], max_new_tokens=N_NEW))
    eng.drain()
    wall = time.perf_counter() - t0
    eng.flush()
    st = eng.stats()
    # One consistent clock for the whole row: measured wall time plus every
    # MODELED stall (never slept, so wall alone would let offload's demand
    # misses ride for free). ttft_s/tpot_s in stats() are charged the same
    # way, so the table's columns agree with the derived e2e/throughput.
    st["e2e_s"] = wall + st["stall_s"]
    st["p99_s"] = float(np.percentile(eng.decode_times, 99)) \
        if eng.decode_times else 0.0
    return st


def _run_mixed(kind, cfg, params):
    """Mixed-length request stream through bucketed admission. The stats
    row carries the structural win: ``prefill_compiles`` (≤ #buckets, not
    #distinct lengths) and ``prefills`` ≪ ``admitted`` (batched
    admission)."""
    import time
    from repro.serving import make_prompts
    eng = InferenceEngine(cfg, clone(params), _backend(kind),
                          EngineConfig(max_slots=4, max_len=96))
    t0 = time.perf_counter()
    for ln in MIXED_LENS:
        eng.submit(Request(
            tokens=make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0],
            max_new_tokens=N_NEW))
    eng.drain()
    wall = time.perf_counter() - t0
    eng.flush()
    st = eng.stats()
    st["e2e_s"] = wall + st["stall_s"]
    st["n_requests"] = float(len(MIXED_LENS))
    st["n_distinct_lengths"] = float(len(set(MIXED_LENS)))
    st["n_buckets"] = float(len(eng.buckets))
    return st


def run(report):
    cfg, params, task = trained_model()
    results = {"schema": list(STAT_KEYS) + ["e2e_s", "p99_s",
                                            "throughput_tps"],
               "smoke": BENCH_SMOKE, "by_batch": {}, "mixed_length": {}}
    for bs in BATCH_SIZES:
        toks = np.asarray(task.sample(bs, PROMPT, seed=bs))
        rows = {}
        for kind in KINDS:
            _run_engine(kind, cfg, params, bs, toks)   # warm-up compile
            st = _run_engine(kind, cfg, params, bs, toks)
            st["throughput_tps"] = bs * N_NEW / st["e2e_s"]
            rows[kind] = st
            report(f"serving/ttft/{kind}/bs{bs}", st["ttft_s"] * 1e6,
                   round(st["ttft_s"], 4))
            # derived column carries the tail (p99 per-step latency)
            report(f"serving/tpot/{kind}/bs{bs}", st["tpot_s"] * 1e6,
                   round(st["p99_s"], 4))
            report(f"serving/stall_s/{kind}/bs{bs}", 0.0,
                   round(st["stall_s"], 5))
            report(f"serving/throughput_tps/{kind}/bs{bs}", 0.0,
                   round(st["throughput_tps"], 2))
        # One comparable table straight from the uniform stats() schema.
        cols = list(STAT_KEYS) + ["p99_s", "throughput_tps"]
        print(f"\n== serving_perf bs={bs} (uniform backend stats) ==")
        print(f"{'backend':>9} " + " ".join(f"{c:>14}" for c in cols))
        for kind in KINDS:
            print(f"{kind:>9} " + " ".join(
                f"{rows[kind].get(c, 0.0):>14.6g}" for c in cols))
        report(f"serving/dynaexq_vs_offload_tput_x/bs{bs}", 0.0,
               round(rows["dynaexq"]["throughput_tps"] /
                     max(rows["offload"]["throughput_tps"], 1e-9), 2))
        results["by_batch"][str(bs)] = rows

    # ---- mixed-length workload: bucketed-admission win ------------------
    # The engine serves KV from the paged block pool by default, so the
    # compile-count guard watches the PAGED prefill entry point.
    from repro.serving.engine import _prefill_paged_jit
    for kind in ("static", "dynaexq"):
        # Real compile-count guard: the warm-up run's ACTUAL jit traces
        # (prefill_shapes bookkeeping alone would track a regression rather
        # than catch it). Measured per kind — each bank pytree traces anew.
        cache_before = _prefill_paged_jit._cache_size()
        _run_mixed(kind, cfg, params)                  # warm-up compile
        new_traces = _prefill_paged_jit._cache_size() - cache_before
        st = _run_mixed(kind, cfg, params)
        st["prefill_traces"] = float(new_traces)
        results["mixed_length"][kind] = st
        report(f"serving/mixed_len/ttft/{kind}", st["ttft_s"] * 1e6,
               round(st["ttft_s"], 4))
        report(f"serving/mixed_len/prefill_compiles/{kind}", 0.0,
               int(new_traces))
        report(f"serving/mixed_len/prefill_calls/{kind}", 0.0,
               int(st["prefills"]))
        if new_traces > st["n_buckets"]:
            raise AssertionError(
                f"{kind}: {int(new_traces)} prefill executables for "
                f"{int(st['n_distinct_lengths'])} distinct lengths — "
                f"bucketed admission regressed (≤{int(st['n_buckets'])} "
                f"buckets expected)")
        print(f"mixed-length/{kind}: {int(st['n_distinct_lengths'])} "
              f"distinct lengths → {int(new_traces)} prefill "
              f"executables ({int(st['n_buckets'])} buckets), "
              f"{int(st['prefills'])} prefill calls for "
              f"{int(st['admitted'])} admissions")

    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    # Read-modify-write: the file is shared with slo_serving (its "slo" key)
    # — clobbering it would silently drop the sibling suite's artifact.
    merged = {}
    if os.path.exists(JSON_OUT):
        try:
            with open(JSON_OUT) as f:
                merged = json.load(f)
        except Exception:
            merged = {}
    merged.update(results)
    with open(JSON_OUT, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(JSON_OUT)}")
