"""Mamba2-130m — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060]"""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab_size=50280,
    d_ff=0,                 # mamba blocks only, no FFN
    attn=None,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=64),
    tie_embeddings=True,
    norm_eps=1e-5,
    max_seq_len=1048576,    # O(1) decode state ⇒ unbounded context
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
)
