from repro.serving.engine import MoEServer, ServeConfig
from repro.serving.requests import WORKLOADS, make_prompts
from repro.serving.offload_baseline import OffloadServer, OffloadConfig

__all__ = ["MoEServer", "ServeConfig", "WORKLOADS", "make_prompts",
           "OffloadServer", "OffloadConfig"]
