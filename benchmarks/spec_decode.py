"""Self-speculative decoding: tokens-per-verify-step and acceptance rate
vs draft depth on the DynaExq backend.

The structural claim: the always-resident lo tier is a free draft model, so
a verify round emits MORE than one token on average (tokens/round > 1) while
the output stays distribution-exact. Decode-heavy traffic (short prompts,
long generations) through the same engine at spec off / k ∈ {2, 4}:

* ``tokens_per_round`` — verified tokens per (round, active-row) pair (the
  uplift: the non-speculative engine is pinned at 1.0);
* ``accept_rate`` — accepted draft fraction (how good int-lo is as a
  speculator for the mixed-precision target);
* wall-clock tokens/s plus the uniform ``stats()`` schema.

Honest caveat on wall clock for THIS container: the jnp oracle path
dequantizes the lo tier to bf16, so drafting costs the same FLOPs as the
target — tokens/s can regress even while tokens/dispatch climbs. The win
this measures is structural (fewer verify dispatches per token, high lo→hi
argmax agreement); converting it into wall-clock needs the int4 compute
path (``kernels/quant_matmul``) under the draft and/or the fused wide
verify (ROADMAP follow-ups).

Rows land in ``experiments/BENCH_spec.json``; ``BENCH_SMOKE=1`` shrinks the
stream for CI.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import BENCH_SMOKE, clone, trained_model
from repro.core import ControllerConfig
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           STAT_KEYS, make_backend, make_prompts)

N_REQ = 4 if BENCH_SMOKE else 12
PROMPT_LEN = 12
N_NEW = 16 if BENCH_SMOKE else 32
SPEC_KS = (0, 2, 4)
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_spec.json")


def _run(cfg, params, spec_k):
    eng = InferenceEngine(
        cfg, clone(params),
        make_backend("dynaexq", lo_bits=4, n_hi_per_layer=2,
                     controller=ControllerConfig(update_interval_s=0.05)),
        # capacity_factor 8: drop-free MoE keeps the draft/verify compute
        # comparable across batch shapes (same caveat as prefix sharing)
        EngineConfig(max_slots=4, max_len=64, capacity_factor=8.0,
                     spec_k=spec_k))
    reqs = [Request(tokens=make_prompts("text", cfg.vocab_size, 1,
                                        PROMPT_LEN, seed=100 + i)[0],
                    max_new_tokens=N_NEW)
            for i in range(N_REQ)]
    t0 = time.perf_counter()
    handles = [eng.submit(r) for r in reqs]
    eng.drain()
    eng.flush()
    wall = time.perf_counter() - t0
    st = eng.stats()
    n_tokens = sum(len(h.tokens) for h in handles)
    st["e2e_s"] = wall + st["stall_s"]
    st["tokens_total"] = float(n_tokens)
    st["tokens_per_s"] = n_tokens / st["e2e_s"]
    # Per-ROW verify-step uplift: tokens emitted per (round, active-row)
    # pair. The non-speculative engine emits exactly 1.0 by definition.
    st["tokens_per_round"] = (st["verified_tokens"] /
                              max(1.0, st["spec_row_rounds"])) if spec_k \
        else 1.0
    return st


def run(report):
    cfg, params, _task = trained_model()
    results = {"schema": list(STAT_KEYS) + [
                   "e2e_s", "tokens_total", "tokens_per_s",
                   "tokens_per_round"],
               "smoke": BENCH_SMOKE, "n_requests": N_REQ,
               "prompt_len": PROMPT_LEN, "new_tokens": N_NEW,
               "variants": {}}
    for k in SPEC_KS:
        _run(cfg, params, k)                     # warm-up compile
        st = _run(cfg, params, k)
        name = f"spec_k{k}" if k else "spec_off"
        results["variants"][name] = st
        report(f"spec_decode/tokens_per_round/{name}", 0.0,
               round(st["tokens_per_round"], 3))
        report(f"spec_decode/accept_rate/{name}", 0.0,
               round(st["accept_rate"], 3))
        report(f"spec_decode/tokens_per_s/{name}", 0.0,
               round(st["tokens_per_s"], 2))
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    best = max(results["variants"][f"spec_k{k}"]["tokens_per_round"]
               for k in SPEC_KS if k)
    print(f"# spec_decode: best tokens/round {best:.2f} "
          f"(spec-off pins 1.0) → {JSON_OUT}")


if __name__ == "__main__":
    run(lambda *a: print(",".join(str(x) for x in a)))
