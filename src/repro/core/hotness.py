"""Long-horizon expert hotness estimation (paper §3.5).

Per-(layer, expert) counters accumulate router selections within a
time-based update interval ``T_u``; at each interval boundary they fold into
an EMA ``S ← α·S + (1−α)·c`` and reset. Host-side numpy: the counters are
tiny ((L, E) int64) and the estimator must not sit on the token critical
path.
"""
from __future__ import annotations

import numpy as np


def mask_row_counts(counts, row_valid=None) -> np.ndarray:
    """Scrub row-resolved router counts: (L, R, E) → (L, E), dropping rows
    where ``row_valid`` ((R,) bool) is False before the sum. Aggregated
    (L, E) input passes through untouched. The ONE place the vacant-slot /
    padding-row scrub rule lives — every consumer (serving backends, the
    hotness estimator) must come through here."""
    c = np.asarray(counts)
    if c.ndim == 3:
        if row_valid is not None:
            c = c * np.asarray(row_valid, bool)[None, :, None]
        c = c.sum(axis=1)
    return c


class HotnessEstimator:
    def __init__(self, n_layers: int, num_experts: int, alpha: float = 0.8):
        if not (0.0 <= alpha < 1.0):
            raise ValueError("alpha must be in [0, 1)")
        self.alpha = alpha
        self.counts = np.zeros((n_layers, num_experts), np.int64)
        self.scores = np.zeros((n_layers, num_experts), np.float64)
        self.intervals = 0

    def observe(self, counts, row_valid=None) -> None:
        """Accumulate one step's router-selection counts.

        Accepts the aggregated (L, E) form, or the serving engine's
        row-resolved (L, R, E) form with an optional ``row_valid`` (R,)
        bool mask — invalid (vacant-slot / padding) rows are dropped before
        the sum so phantom traffic never reaches the EMA."""
        c = mask_row_counts(counts, row_valid)
        if c.shape != self.counts.shape:
            raise ValueError(f"counts shape {c.shape} != {self.counts.shape}")
        self.counts += c.astype(np.int64)

    def fold(self) -> np.ndarray:
        """Interval boundary: fold counters into the EMA and reset."""
        self.scores = self.alpha * self.scores + (1 - self.alpha) * self.counts
        self.counts[:] = 0
        self.intervals += 1
        return self.scores

    def swap(self, layer: int, e: int, f: int) -> None:
        """Relabel two experts at ``layer`` (EP ownership migration swaps
        positions everywhere — the EMA history must follow its expert)."""
        self.scores[layer, [e, f]] = self.scores[layer, [f, e]]
        self.counts[layer, [e, f]] = self.counts[layer, [f, e]]

    # -- persistence (cold-start priors) ---------------------------------
    def state_dict(self) -> dict:
        """EMA + unfolded counters, serializable with ``np.savez``."""
        return {"alpha": np.float64(self.alpha),
                "counts": self.counts.copy(),
                "scores": self.scores.copy(),
                "intervals": np.int64(self.intervals)}

    def load_state(self, state: dict) -> None:
        """Restore a previous run's traffic history. Shapes must match the
        live estimator (a resized model must not inherit stale priors)."""
        scores = np.asarray(state["scores"], np.float64)
        counts = np.asarray(state["counts"], np.int64)
        if scores.shape != self.scores.shape:
            raise ValueError(
                f"hotness state shape {scores.shape} != "
                f"{self.scores.shape}")
        self.scores = scores.copy()
        self.counts = counts.copy()
        self.intervals = int(state.get("intervals", 0))

    def save(self, path: str) -> None:
        np.savez(path, **self.state_dict())

    def load(self, path: str) -> None:
        with np.load(path) as z:
            self.load_state({k: z[k] for k in z.files})
