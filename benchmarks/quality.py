"""Paper Table 4: quality at equal device-memory footprint.

FP16 / static Int4 / static Int2 / DynaExq (Int2 lo tier + budget-limited
FP16 hot set, hotness-driven). The paper's headline: DynaExq under the Int2
budget recovers most of the Int4-level quality (73.09 → 77.57 on Qwen3-80B);
here the metric is held-out perplexity of the trained bench model.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import eval_batches, trained_model
from benchmarks.quality_common import (bank_with_hotset, hotness_from_counts,
                                       ppl, stack_experts)
from repro.core.ver import expert_hi_nbytes, expert_lo_nbytes


def run(report):
    cfg, params, task = trained_model()
    E = cfg.moe.num_experts
    L = cfg.n_layers

    t0 = time.perf_counter()
    results = {}
    results["fp16"] = ppl(cfg, params, eval_batches(task, cfg, n=4))
    # static tiers: uniform lo, empty hi pool
    for bits in (4, 2):
        bank = bank_with_hotset(params, lo_bits=bits, hi_sets=[[] for _ in range(L)])
        results[f"int{bits}"] = ppl(cfg, params, eval_batches(task, cfg, n=4), bank)
    # DynaExq: int2 lo + hot quarter of experts in fp16
    hot = hotness_from_counts(cfg, params, eval_batches(task, cfg, n=3))
    n_hi = E // 4
    hi_sets = [[int(e) for e in np.argsort(-hot[l])[:n_hi]] for l in range(L)]
    bank = bank_with_hotset(params, lo_bits=2, hi_sets=hi_sets)
    results["dynaexq_int2_hot_fp16"] = ppl(cfg, params,
                                           eval_batches(task, cfg, n=4), bank)
    # the paper's Qwen3-80B tier pair: Int4 hi / Int2 lo — strictly BELOW the
    # uniform-Int4 budget
    bank4 = bank_with_hotset(params, lo_bits=2, hi_sets=hi_sets, hi_bits=4)
    results["dynaexq_int2_hot_int4"] = ppl(cfg, params,
                                           eval_batches(task, cfg, n=4), bank4)
    dt = time.perf_counter() - t0

    for k, v in results.items():
        report(f"quality/ppl/{k}", 0.0, round(v, 3))

    # footprint accounting (same budget story as the paper's Table 3/4)
    shapes = {n: tuple(a.shape) for n, a in stack_experts(params).items()}
    lo2 = expert_lo_nbytes(shapes, 2) * L * E
    lo4 = expert_lo_nbytes(shapes, 4) * L * E
    hi = expert_hi_nbytes(shapes) * L * n_hi
    fp16 = expert_hi_nbytes(shapes) * L * E
    hi4 = expert_lo_nbytes(shapes, 4) * L * n_hi
    report("quality/bytes/fp16", 0.0, fp16)
    report("quality/bytes/int4", 0.0, lo4)
    report("quality/bytes/dynaexq_hot_fp16", 0.0, lo2 + hi)
    report("quality/bytes/dynaexq_hot_int4", 0.0, lo2 + hi4)
    # headline: fraction of the int2→int4 quality gap recovered by DynaExq
    gap = results["int2"] - results["int4"]
    rec = results["int2"] - results["dynaexq_int2_hot_fp16"]
    report("quality/gap_recovered_frac", dt * 1e6,
           round(rec / gap, 3) if gap > 1e-6 else 1.0)
