"""Paper Tables 1 & 2: expert-activation ratio vs batch size, decode and
prefill. Reproduces the densification observation — the regime where
offloading/prefetching loses to resident mixed precision."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import clone, trained_model
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           make_backend)


def run(report):
    cfg, params, task = trained_model()
    rows = {}
    for stage in ("decode", "prefill"):
        for bs in (1, 2, 4, 8, 16, 32):
            eng = InferenceEngine(cfg, clone(params), make_backend("fp16"),
                                  EngineConfig(max_slots=bs, max_len=96))
            toks = np.asarray(task.sample(bs, 32, seed=bs))
            n_new = 2 if stage == "decode" else 1
            t0 = time.perf_counter()
            for b in range(bs):
                eng.submit(Request(tokens=toks[b], max_new_tokens=n_new))
            eng.drain()
            dt = time.perf_counter() - t0
            if stage == "decode":
                # Router counts of the last decode step (all bs slots live).
                counts = np.asarray(eng.last_counts["0"])        # (L, E)
            else:
                counts = np.asarray(eng.backend.router_counts()["0"])
            ratio = float((counts > 0).mean())
            rows[(stage, bs)] = ratio
            report(f"activation_ratio/{stage}/bs{bs}", dt * 1e6,
                   round(ratio * 100, 1))
    # densification factor (paper: ratio grows sharply with batch)
    for stage in ("decode", "prefill"):
        report(f"activation_ratio/{stage}/densification_x",
               0.0, round(rows[(stage, 32)] / max(rows[(stage, 1)], 1e-9), 2))
