from repro.serving.backends import (BACKENDS, DynaExqBackend, Fp16Backend,
                                    LRUSet, OffloadBackend, OffloadConfig,
                                    ResidencyBackend, STAT_KEYS,
                                    StaticPTQBackend, make_backend)
from repro.serving.engine import (EngineConfig, InferenceEngine,
                                  RequestHandle, RequestState)
from repro.serving.kvpool import KVBlockPool, KVLease, TRASH_BLOCK
from repro.serving.prefix import PrefixTrie
from repro.serving.requests import (Request, RequestStream, WORKLOADS,
                                    make_prompts, mixed_stream)
from repro.serving.sampler import (GREEDY, RequestSampler, SamplingParams,
                                   counter_uniform, sampling_probs)
from repro.serving.spec import SpecDecoder, accept_burst, all_lo_banks

__all__ = [
    "BACKENDS", "DynaExqBackend", "EngineConfig", "Fp16Backend", "GREEDY",
    "InferenceEngine", "KVBlockPool", "KVLease", "LRUSet", "OffloadBackend",
    "OffloadConfig", "PrefixTrie", "Request", "RequestHandle",
    "RequestSampler", "RequestState", "RequestStream", "ResidencyBackend",
    "STAT_KEYS", "SamplingParams", "SpecDecoder", "StaticPTQBackend",
    "TRASH_BLOCK", "WORKLOADS", "accept_burst", "all_lo_banks",
    "counter_uniform", "make_backend", "make_prompts", "mixed_stream",
    "sampling_probs",
]
