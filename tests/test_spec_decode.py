"""Self-speculative decoding: token-identity with the non-speculative path
at temperature=0 (full-attention / sliding-window / mamba stacks, paged and
dense), KV-pool invariance after rewind, EOS-mid-burst truncation, stats
surfacing, and rejection-sampling plumbing.

Identity caveats (both documented): MoE capacity drops are compute-batch
dependent, so tests run drop-free (capacity_factor=8); and an ONLINE
residency controller makes the target model a function of its own serving
history (observe/tick cadence), so the mixed-precision identity test warms
the hi tier then freezes the policy — the drafts still run all-lo, so
rejection genuinely happens against a time-invariant mixed target."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           SamplingParams, make_backend, make_prompts)
from repro.serving.sampler import RequestSampler
from repro.serving.spec import accept_burst

ARCHS = {}


def _setup(arch):
    if arch not in ARCHS:
        cfg = get_config(arch, reduced=True)
        ARCHS[arch] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    cfg, params = ARCHS[arch]
    return cfg, jax.tree_util.tree_map(lambda x: x, params)


def _engine(arch, spec_k, paged=True, backend=None, max_slots=2,
            max_len=96, **ecfg_kw):
    cfg, params = _setup(arch)
    be = make_backend("fp16") if backend is None else backend()
    eng = InferenceEngine(cfg, params, be,
                          EngineConfig(max_slots=max_slots, max_len=max_len,
                                       capacity_factor=8.0, spec_k=spec_k,
                                       paged=paged, **ecfg_kw))
    return cfg, eng


def _serve(cfg, eng, lengths=(24, 17, 21), new=10, seed=7, **req_kw):
    """Three requests over two slots: the third admits into a freed slot
    mid-stream, so every identity test also covers spec rounds across a
    continuous-batching refill."""
    handles = [eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, L, seed=seed + s)[0],
        max_new_tokens=new, **req_kw))
        for s, L in enumerate(lengths)]
    eng.drain()
    return [h.tokens for h in handles]


# ---------------------------------------------------------------------------
# Greedy token-identity, all three stack types
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,paged", [
    ("granite-moe-1b-a400m", True),      # full attention, paged pool
    ("granite-moe-1b-a400m", False),     # full attention, dense rows
    ("h2o-danube-3-4b", True),           # sliding-window ring, paged
    ("h2o-danube-3-4b", False),          # sliding-window ring, dense
    ("mamba2-130m", False),              # pure SSM (no KV at all)
])
def test_spec_token_identity_greedy(arch, paged):
    cfg, eng_off = _engine(arch, spec_k=0, paged=paged)
    off = _serve(cfg, eng_off)
    cfg, eng_on = _engine(arch, spec_k=4, paged=paged)
    on = _serve(cfg, eng_on)
    assert off == on
    st = eng_on.stats()
    assert st["spec_rounds"] > 0
    assert st["verified_tokens"] > st["spec_rounds"]  # >1 token/round


def test_spec_token_identity_jamba_mixed_stack():
    """Mixed mamba+attention: SSM snapshot/rollback and KV rewind in the
    same round."""
    cfg, eng_off = _engine("jamba-v0_1-52b", spec_k=0)
    off = _serve(cfg, eng_off, new=8)
    cfg, eng_on = _engine("jamba-v0_1-52b", spec_k=3)
    on = _serve(cfg, eng_on, new=8)
    assert off == on


def test_spec_identity_against_frozen_mixed_precision_target():
    """The real DynaExq shape: draft on the all-lo tier, verify against a
    WARMED mixed-precision bank (hi tier populated, policy then frozen so
    the target is time-invariant). Rejections must actually occur — the
    draft model genuinely differs — and the emitted tokens must still equal
    the non-speculative engine's."""
    def backend():
        return make_backend("dynaexq", lo_bits=4, n_hi_per_layer=2,
                            controller=ControllerConfig(
                                update_interval_s=0.0))

    def build(spec_k):
        cfg, eng = _engine("granite-moe-1b-a400m", spec_k=spec_k,
                           backend=backend, max_slots=2, max_len=96)
        warm = make_prompts("text", cfg.vocab_size, 2, 16, seed=99)
        eng.generate({"tokens": warm}, 4)
        eng.backend.force_update()
        eng.backend.flush()
        for ctl in eng.backend.controllers.values():
            ctl.cfg = dataclasses.replace(ctl.cfg, update_interval_s=1e9)
        return cfg, eng

    cfg, eng_off = build(0)
    off = _serve(cfg, eng_off, lengths=(20, 13))
    cfg, eng_on = build(4)
    on = _serve(cfg, eng_on, lengths=(20, 13))
    assert off == on
    st = eng_on.stats()
    assert st["draft_tokens"] > 0
    # hi tier is populated, so lo-draft vs mixed-target must disagree
    # somewhere (otherwise this test is vacuous)
    assert st["accept_rate"] < 1.0
    assert 0.0 < st["accept_rate"]


# ---------------------------------------------------------------------------
# KV pool: no leaked blocks / refcounts after rewind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "h2o-danube-3-4b"])
def test_kvpool_invariant_after_spec_rewind(arch):
    cfg, eng_off = _engine(arch, spec_k=0, paged=True)
    _serve(cfg, eng_off, new=12)
    cfg, eng_on = _engine(arch, spec_k=4, paged=True)
    _serve(cfg, eng_on, new=12)
    eng_on.pool.check_invariants()
    # Every lease closed: spec-on must hold exactly the blocks spec-off
    # does (trie-retained prefix chunks only) — rejected-tail blocks were
    # unwound/released, refcounts fully unwound, quota fully returned.
    assert eng_on.pool.blocks_in_use == eng_off.pool.blocks_in_use
    assert eng_on.pool.quota_blocks == 0
    np.testing.assert_array_equal(np.sort(eng_on.pool.refcount),
                                  np.sort(eng_off.pool.refcount))


def test_spec_unwinds_rejected_tail_blocks():
    """Force tiny blocks so a draft burst regularly crosses a block
    boundary; rejected-tail blocks must flow back (pool stats see either
    unwinds or zero crossings, and invariants always hold mid-flight)."""
    cfg, eng = _engine("granite-moe-1b-a400m", spec_k=4, paged=True,
                       block_tokens=4, max_slots=1)
    h = eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, 18, seed=3)[0],
        max_new_tokens=16))
    while h.state.value != "finished":
        eng.step()
        eng.pool.check_invariants()
    assert len(h.tokens) == 16


# ---------------------------------------------------------------------------
# EOS mid-burst truncation
# ---------------------------------------------------------------------------

def test_eos_mid_burst_truncates_at_first_occurrence():
    # Find a token the greedy continuation emits mid-stream, then rerun
    # with that token as EOS: both engines must truncate identically even
    # though the speculative engine accepted it mid-burst.
    cfg, eng = _engine("granite-moe-1b-a400m", spec_k=0)
    base = _serve(cfg, eng, lengths=(20,), new=12)[0]
    eos = base[len(base) // 2]                   # appears mid-generation
    want = base[:base.index(eos) + 1]

    cfg, eng_off = _engine("granite-moe-1b-a400m", spec_k=0)
    off = _serve(cfg, eng_off, lengths=(20,), new=12, eos_token_id=eos)[0]
    cfg, eng_on = _engine("granite-moe-1b-a400m", spec_k=4)
    h = eng_on.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, 20, seed=7)[0],
        max_new_tokens=12, eos_token_id=eos))
    eng_on.drain()
    on = h.tokens
    assert off == want
    assert on == want
    assert on[-1] == eos and eos not in on[:-1]
    # Discarded post-EOS tokens must not linger in per-token accounting:
    # one step_times entry per DECODE-emitted kept token (the first token
    # comes from prefill and is tracked by ttft instead).
    assert len(h.step_times) == len(h.tokens) - 1
    assert eng_on.stats()["verified_tokens"] <= len(h.tokens) - 1


# ---------------------------------------------------------------------------
# Stats + sampling integration
# ---------------------------------------------------------------------------

def test_spec_stats_in_uniform_schema():
    from repro.serving import STAT_KEYS
    for key in ("accept_rate", "draft_tokens", "verified_tokens",
                "spec_rounds"):
        assert key in STAT_KEYS
    cfg, eng = _engine("granite-moe-1b-a400m", spec_k=3)
    _serve(cfg, eng)
    st = eng.stats()
    assert st["spec_rounds"] > 0 and st["draft_tokens"] > 0
    assert 0.0 <= st["accept_rate"] <= 1.0
    assert st["verified_tokens"] >= st["spec_rounds"]
    # spec-off engines still carry the schema keys (zeros)
    cfg, eng0 = _engine("granite-moe-1b-a400m", spec_k=0)
    _serve(cfg, eng0)
    assert eng0.stats()["spec_rounds"] == 0.0


def test_spec_sampled_decode_is_deterministic_per_seed():
    """temperature>0 + speculation: rejection sampling draws from
    counter-keyed streams, so a full rebuild reproduces the tokens."""
    def run():
        cfg, eng = _engine("granite-moe-1b-a400m", spec_k=3)
        return _serve(cfg, eng, new=8,
                      sampling=SamplingParams(temperature=0.9, seed=42))
    a, b = run(), run()
    assert a == b
    assert all(len(t) == 8 for t in a)


def test_spec_sampled_reproducible_across_batch_compositions():
    """Adaptive speculation must not leak batch composition into sampled
    outputs: draft depth comes from each request's OWN acceptance EMA, so
    the same request consumes identical PRNG streams alone or crowded
    (frozen mixed-precision target keeps acceptance genuinely variable)."""
    def backend():
        return make_backend("dynaexq", lo_bits=4, n_hi_per_layer=2,
                            controller=ControllerConfig(
                                update_interval_s=0.0))

    def build():
        cfg, eng = _engine("granite-moe-1b-a400m", spec_k=4,
                           backend=backend, max_slots=3, max_len=96)
        warm = make_prompts("text", cfg.vocab_size, 2, 16, seed=99)
        eng.generate({"tokens": warm}, 4)
        eng.backend.force_update()
        eng.backend.flush()
        for ctl in eng.backend.controllers.values():
            ctl.cfg = dataclasses.replace(ctl.cfg, update_interval_s=1e9)
        return cfg, eng

    cfg, eng = build()
    target = Request(tokens=make_prompts("text", cfg.vocab_size, 1, 18,
                                         seed=5)[0],
                     max_new_tokens=10,
                     sampling=SamplingParams(temperature=0.8, seed=777))
    alone = eng.submit(target)
    eng.drain()

    cfg, eng2 = build()
    others = [Request(tokens=make_prompts("math", cfg.vocab_size, 1, n,
                                          seed=n)[0],
                      max_new_tokens=10,
                      sampling=SamplingParams(temperature=0.8, seed=n))
              for n in (11, 23)]
    hs = [eng2.submit(r) for r in (others[0], target, others[1])]
    eng2.drain()
    assert alone.tokens == hs[1].tokens


def test_accept_burst_rejection_math():
    """Unit check of the acceptance rule: greedy draft proposal ⇒ accept
    prob p(d), residual = p minus the draft token, renormalized."""
    sampler = RequestSampler(SamplingParams(temperature=1.0, seed=0))
    V = 8
    logits = np.zeros((3, V), np.float32)
    logits[:, 0] = 10.0                           # p ≈ one-hot at 0
    drafts = np.array([0, 0], np.int32)
    a, out = accept_burst(sampler, drafts, logits)
    assert a == 2 and len(out) == 3               # all accepted + bonus
    assert out == [0, 0, 0]

    # draft disagrees with a near-deterministic target → rejected at j=0,
    # exactly one corrected token emitted, never the draft token
    drafts = np.array([3, 3], np.int32)
    a, out = accept_burst(sampler, drafts, logits)
    assert a == 0 and len(out) == 1
    assert out[0] != 3

    # greedy params: pure argmax agreement
    g = RequestSampler(SamplingParams(temperature=0.0))
    logits = np.random.default_rng(0).normal(size=(4, V)).astype(np.float32)
    drafts = np.argmax(logits[:3], -1).astype(np.int32)
    a, out = accept_burst(g, drafts, logits)
    assert a == 3
    assert out == [int(np.argmax(logits[j])) for j in range(4)]


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "h2o-danube-3-4b"])
def test_spec_round_touches_only_accepted_slots_dense(arch):
    """Invariant: a speculative round may change a live row's dense cache
    ONLY at the slots of the tokens it accepted ([pos_before, pos_after) mod
    C) — every other slot must be bit-identical before/after the round.
    This directly catches the whole non-accepted-write class: rejected-tail
    lanes, beyond-depth lanes a shallow row rides on a deeper row's burst,
    and the wrap of those lanes onto LIVE low slots when a row sits near
    its sequence cap ((pos + j) % C in full caches, any wrap in rings)."""
    cfg, eng = _engine(arch, spec_k=4, paged=False, max_slots=2, max_len=24)
    # Row 0 admitted near the cap (depth clamps to max_len-1-pos while row 1
    # drafts deep), row 1 with full headroom.
    handles = [eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, L, seed=31 + L)[0],
        max_new_tokens=12)) for L in (18, 6)]
    C = eng._C_attn
    spec_rounds_checked = 0
    for _ in range(64):
        if all(h.state.value == "finished" for h in handles):
            break
        live = {i: h for i, h in enumerate(eng.slots) if h is not None}
        pos_before = eng.pos.copy()
        before = {p: (np.asarray(eng.caches.blocks[p].k),
                      np.asarray(eng.caches.blocks[p].v))
                  for p in eng._attn_pos}
        rounds0 = eng._spec.rounds
        eng.step()
        if eng._spec.rounds == rounds0:
            continue                     # single-token fallback step
        spec_rounds_checked += 1
        for i, h in live.items():
            # pos advanced by exactly the accepted+bonus tokens; _finish
            # does not reset it, so the range is valid even for rows that
            # completed during the round. (Rows admitted THIS step are not
            # in `live` and are not checked — their cache row was fully
            # rewritten by admission.)
            allowed = {int(p) % C
                       for p in range(int(pos_before[i]), int(eng.pos[i]))}
            keep = np.asarray([s not in allowed for s in range(C)], bool)
            for p in eng._attn_pos:
                for arr, name in ((eng.caches.blocks[p].k, "k"),
                                  (eng.caches.blocks[p].v, "v")):
                    after = np.asarray(arr)
                    idx = 0 if name == "k" else 1
                    np.testing.assert_array_equal(
                        after[:, i, :, keep], before[p][idx][:, i, :, keep],
                        err_msg=f"row {i} {name} pos {p}: non-accepted "
                                f"slot changed (allowed={sorted(allowed)})")
    assert spec_rounds_checked > 0


def test_spec_identity_near_sequence_cap_dense():
    """A row close to its sequence cap rides a deeper row's burst beyond
    its own depth; in a DENSE full cache those extra lanes wrap
    ``(pos + j) % C`` onto live low slots and must be restored, or the
    row's remaining decode reads clobbered context. Frozen mixed-precision
    target keeps rejections real (partial acceptance leaves rows alive
    past wrapped lanes) while the trajectory stays time-invariant."""
    def backend():
        return make_backend("dynaexq", lo_bits=4, n_hi_per_layer=2,
                            controller=ControllerConfig(
                                update_interval_s=0.0))

    def build(spec_k):
        cfg, eng = _engine("granite-moe-1b-a400m", spec_k=spec_k,
                           paged=False, backend=backend, max_slots=3,
                           max_len=48)
        warm = make_prompts("text", cfg.vocab_size, 2, 16, seed=99)
        eng.generate({"tokens": warm}, 4)
        eng.backend.force_update()
        eng.backend.flush()
        for ctl in eng.backend.controllers.values():
            ctl.cfg = dataclasses.replace(ctl.cfg, update_interval_s=1e9)
        return cfg, eng

    cfg, eng_off = build(0)
    off = _serve(cfg, eng_off, lengths=(40, 8, 36), new=12)
    cfg, eng_on = build(4)
    on = _serve(cfg, eng_on, lengths=(40, 8, 36), new=12)
    assert off == on


def test_spec_headroom_fallback_single_token():
    """max_new_tokens=1 leaves no draft headroom: the engine must fall back
    to the plain single-token step and still finish correctly."""
    cfg, eng = _engine("granite-moe-1b-a400m", spec_k=4)
    toks = _serve(cfg, eng, lengths=(12, 9), new=1)
    assert all(len(t) == 1 for t in toks)
    assert eng.stats()["spec_rounds"] == 0.0
