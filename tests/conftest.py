import os

# Tests run single-device: the multi-device dry-run tests spawn subprocesses
# with their own XLA_FLAGS (jax locks device count at first init).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
