"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtensor import unpack_bits


def dequant_ref(packed: jax.Array, scales: jax.Array, bits: int,
                group: int) -> jax.Array:
    """packed: (..., K//epb, N) uint8; scales: (..., K//g, N) → (..., K, N) f32."""
    epb = 8 // bits
    *lead, kp, n = packed.shape
    k = kp * epb
    u = unpack_bits(packed, bits, k)
    q = u - (1 << (bits - 1))
    qf = q.reshape(*lead, k // group, group, n).astype(jnp.float32)
    return (qf * scales[..., :, None, :].astype(jnp.float32)).reshape(*lead, k, n)


def quant_matmul_ref(x: jax.Array, packed: jax.Array, scales: jax.Array,
                     bits: int, group: int) -> jax.Array:
    """x: (M, K) × quantized (K, N) → (M, N) f32-accumulated, x.dtype out."""
    w = dequant_ref(packed, scales, bits, group)
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def grouped_quant_matmul_ref(xg: jax.Array, packed: jax.Array,
                             scales: jax.Array, bits: int,
                             group: int) -> jax.Array:
    """xg: (E, C, K) × quantized (E, K, N) → (E, C, N)."""
    w = dequant_ref(packed, scales, bits, group)
    return jnp.einsum("eck,ekn->ecn", xg.astype(jnp.float32), w).astype(xg.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, Hkv, hd); valid: (B, S) bool → (B, H, hd)."""
    B, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
