"""Engine-step-driven watchdog for stuck transfers and stuck requests.

`InferenceEngine.step()` calls ``scan(engine)`` once per step (only when a
deadline is configured — the disabled path is a ``None`` pointer check).
Two sweeps:

* **Promotions** stuck in flight past ``promo_deadline_s`` (engine-clock
  age since issue) are cancelled through the backend's
  ``cancel_stuck_promotions`` hook — the slot frees, the reservation
  refunds exactly once, and the expert keeps serving lo.  Emits a
  ``promo_timeout`` event per cancel.
* **Requests** RUNNING but with no token appended for ``no_progress_s``
  are preempted back to the front of their QoS tier (bit-exact snapshot
  resume — the request is requeued, not failed).  Emits ``watchdog_cancel``.

All ages are measured on the engine clock, so virtual-clock replays see the
same watchdog decisions as realtime runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    promo_deadline_s: Optional[float] = None
    no_progress_s: Optional[float] = None


class Watchdog:
    def __init__(self, cfg: WatchdogConfig, tracer=None):
        self.cfg = cfg
        self.tracer = tracer
        self.stats = {"promo_timeouts": 0, "request_requeues": 0}

    def scan(self, engine) -> int:
        """One sweep over in-flight promotions and RUNNING requests.
        Returns the number of cancels/requeues performed."""
        now = engine._now()
        n = 0
        if self.cfg.promo_deadline_s is not None:
            cancel = getattr(engine.backend, "cancel_stuck_promotions", None)
            if cancel is not None:
                k = cancel(now, self.cfg.promo_deadline_s)
                self.stats["promo_timeouts"] += k
                n += k
        if self.cfg.no_progress_s is not None:
            for h in list(engine.slots):
                # Only requests that produced at least one token carry a
                # progress stamp; younger ones are still covered by the
                # admission-stall detector.
                if h is None or not h.last_progress_s:
                    continue
                if h.state.value != "running":
                    continue
                age = now - h.last_progress_s
                if age <= self.cfg.no_progress_s:
                    continue
                engine.preempt(h)
                h.last_progress_s = now
                engine.counters["watchdog_cancels"] += 1
                self.stats["request_requeues"] += 1
                n += 1
                if self.tracer is not None:
                    self.tracer.instant("watchdog_cancel", cat="fault",
                                        rid=h.id, age_s=round(age, 6))
        return n
