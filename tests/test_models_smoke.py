"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED variant
of each family runs one forward + one train step on CPU; shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import forward_train, init_params
from repro.models.frontend import audio_frame_embeddings, image_patch_embeddings
from repro.training import TrainConfig, make_train_step
from repro.training.adamw import AdamWConfig, adamw_init


def _batch(cfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio_embeds"] = audio_frame_embeddings(key, cfg, B)
    if cfg.family == "vlm":
        batch["image_embeds"] = image_patch_embeddings(key, cfg, B)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= max(2, len(cfg.superblock_or_default()))
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S, key)
    logits, aux = forward_train(params, cfg, batch)
    S_out = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()

    # one train step
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size) \
        if cfg.family != "vlm" else batch["tokens"]
    if cfg.family == "vlm":
        batch["labels"] = batch["tokens"]
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = adamw_init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "qwen3-moe-30b-a3b": (48, 2048, 151936),
        "h2o-danube-3-4b": (24, 3840, 32000),
        "granite-moe-1b-a400m": (24, 1024, 49155),
        "llama3_2-3b": (28, 3072, 128256),
        "whisper-tiny": (4, 384, 51865),
        "deepseek-7b": (30, 4096, 102400),
        "jamba-v0_1-52b": (32, 4096, 65536),
        "phi4-mini-3.8b": (32, 3072, 200064),
        "mamba2-130m": (24, 768, 50280),
        "llava-next-34b": (60, 7168, 64000),
        "qwen3-moe-80b-a3b": (48, 2048, 151936),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab_size) == expected
    assert cfg.source  # every config cites its source


def test_moe_configs_match_assignment():
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.moe.num_experts, q.moe.top_k, q.moe.d_ff_expert) == (128, 8, 768)
    g = get_config("granite-moe-1b-a400m")
    assert (g.moe.num_experts, g.moe.top_k) == (32, 8)
    j = get_config("jamba-v0_1-52b")
    assert (j.moe.num_experts, j.moe.top_k) == (16, 2)
    assert j.superblock.count("mamba") == 7 and j.superblock.count("attn") == 1
    m = get_config("mamba2-130m")
    assert m.ssm.d_state == 128 and m.attn is None


def test_param_counts_in_expected_range():
    """6ND accounting sanity: totals should be within ~25% of the advertised
    model sizes (vocab/arch approximations explain the slack)."""
    expect = {"qwen3-moe-30b-a3b": 30e9, "llama3_2-3b": 3.2e9,
              "deepseek-7b": 7e9, "mamba2-130m": 0.13e9,
              "llava-next-34b": 34e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * n < got < 1.45 * n, (arch, got, n)
